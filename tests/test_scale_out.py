"""Hierarchical scale-out (DESIGN.md §13): hierarchy ownership invariants,
mmap-store lifecycle + attach parity, the DP exchange protocol, restricted
per-trainer rebuild bit-identity, and 2-trainer data-parallel fit parity
against the single-process trajectory."""

import multiprocessing as mp
import os
import threading

import numpy as np
import pytest

from repro.api import Heta, HetaConfig
from repro.core.meta_partition import hierarchical_partition
from repro.graph.synthetic import mag240m_stream, ogbn_mag_like

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="no /dev/shm on this platform"
)


def _quick_cfg(steps=3, **scale):
    cfg = HetaConfig.from_dict(dict(
        data=dict(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                  batch_size=16),
        model=dict(hidden=16, num_heads=2, train_learnable=False),
        run=dict(executor="raf_spmd", steps=steps, seed=11, log_every=0),
        pipeline=dict(num_workers=0),
    ))
    return cfg.updated(scale=scale) if scale else cfg


def _built(cfg):
    sess = Heta(cfg)
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    return sess


# --------------------------------------------------------------------------
# hierarchy ownership
# --------------------------------------------------------------------------


def test_hierarchy_ownership_invariant():
    """Every node owned by exactly one (group, sub); rank seed slices are
    disjoint and their concatenation is a permutation of train_nodes."""
    g = ogbn_mag_like(scale=0.002)
    hier = hierarchical_partition(g, num_groups=2, trainers_per_group=2,
                                  num_layers=2, seed=3)
    hier.validate_ownership(g)
    slices = [hier.trainer_train_nodes(g, r)
              for r in range(hier.num_trainers)]
    allid = np.concatenate(slices)
    assert len(allid) == len(g.train_nodes)
    assert len(np.unique(allid)) == len(allid)  # disjoint
    assert np.array_equal(np.sort(allid), np.sort(g.train_nodes))
    for r, s in enumerate(slices):
        ranks = hier.rank_of(g.target_type, s)
        assert (ranks == r).all()


def test_hierarchy_rank_out_of_range():
    g = ogbn_mag_like(scale=0.002)
    hier = hierarchical_partition(g, 2, 2)
    with pytest.raises(ValueError):
        hier.trainer_train_nodes(g, 4)


# --------------------------------------------------------------------------
# mmap store: attach parity, num_nodes ordering, janitor
# --------------------------------------------------------------------------


def test_mmap_attach_parity_and_order():
    """Attached twin is bit-equal AND iterates node types in the source
    graph's insertion order (type-arena offsets depend on it)."""
    from repro.graph.mmap_store import attach_any, live_stores, mmap_share_graph

    g = ogbn_mag_like(scale=0.002)
    store = mmap_share_graph(g, include_features=True)
    try:
        att = attach_any(store.handle)
        assert list(att.graph.num_nodes) == list(g.num_nodes)
        assert att.graph.num_nodes == g.num_nodes
        for r, csr in g.relations.items():
            np.testing.assert_array_equal(csr.indices,
                                          att.graph.relations[r].indices)
        for t, f in g.features.items():
            np.testing.assert_array_equal(f, att.graph.features[t])
        np.testing.assert_array_equal(g.train_nodes, att.graph.train_nodes)
        att.close()
    finally:
        store.unlink()
    assert store.handle.path.split(os.sep)[-1] not in live_stores()


def test_shm_handle_preserves_num_nodes_order():
    from repro.graph.shm import attach, share_graph

    g = ogbn_mag_like(scale=0.002)
    with share_graph(g, include_features=False) as store:
        att = attach(store.handle)
        assert list(att.graph.num_nodes) == list(g.num_nodes)
        att.close()


def test_mmap_janitor_reaps_dead_owner_store():
    from repro.graph import mmap_store as ms

    g = ogbn_mag_like(scale=0.002)
    store = ms.mmap_share_graph(g, include_features=False)
    name = os.path.basename(store.handle.path)
    try:
        # alive owner: never reaped
        assert name not in ms.cleanup_stale_stores()
        # forge a dead-owner name in the same root
        dead = name.replace(f"{os.getpid():x}", "3ffffffe", 1)
        os.rename(store.handle.path, os.path.join(
            os.path.dirname(store.handle.path), dead))
        assert dead in ms.cleanup_stale_stores()
        assert dead not in ms.live_stores()
    finally:
        store.unlink()


def test_mag240m_stream_tiny_attaches():
    """The chunk-wise builder commits a well-formed store at tiny scale."""
    from repro.graph.mmap_store import attach_any

    store = mag240m_stream(scale=1e-6, chunk_edges=128)
    try:
        att = attach_any(store.handle)
        g = att.graph
        assert g.target_type == "paper"
        assert set(g.num_nodes) == {"paper", "author", "institution"}
        for csr in g.relations.values():
            n_src = csr.indptr.size - 1
            assert csr.indptr[0] == 0
            assert (np.diff(csr.indptr) >= 0).all()
            assert n_src in g.num_nodes.values() or n_src > 0
        att.close()
    finally:
        store.unlink()


# --------------------------------------------------------------------------
# DP exchange protocol (threads stand in for processes; same Condition)
# --------------------------------------------------------------------------


def test_dp_exchange_fixed_order_reduction():
    from repro.data.dp_trainer import attach_exchange, create_exchange

    leaves = [np.zeros((4, 3), np.float32), np.zeros((2,), np.float64)]
    cond = mp.get_context("spawn").Condition()
    ex0 = create_exchange(leaves, num_ranks=2, cond=cond, depth=2)
    ex1 = attach_exchange(ex0.handle, cond, rank=1, template_leaves=leaves)
    steps, got = 5, {}

    def rank_main(ex, rank):
        rng = np.random.default_rng(100 + rank)
        out = []
        for k in range(steps):
            mine = [rng.standard_normal((4, 3)).astype(np.float32),
                    rng.standard_normal(2)]
            ex.contribute(k, mine, order=rank, num_contrib=2,
                          loss=float(rank + k), batch_size=8)
            red, loss_row, bs_row = ex.consume(k)
            out.append((mine, red, loss_row.copy(), bs_row.copy()))
        got[rank] = out

    t = threading.Thread(target=rank_main, args=(ex1, 1), daemon=True)
    t.start()
    rank_main(ex0, 0)
    t.join(timeout=30)
    assert not t.is_alive()
    for k in range(steps):
        m0, r0, l0, b0 = got[0][k]
        m1, r1, _, _ = got[1][k]
        # fixed order: rank0 copy then rank1 += — both see identical sums
        expect = [m0[i] + m1[i] for i in range(2)]
        for i in range(2):
            np.testing.assert_array_equal(r0[i], expect[i])
            np.testing.assert_array_equal(r1[i], expect[i])
        assert list(l0) == [float(k), float(1 + k)]
        assert list(b0) == [8, 8]
    ex1.close()
    ex0.unlink()


def test_dp_exchange_template_mismatch_fails_fast():
    from repro.data.dp_trainer import DPError, attach_exchange, create_exchange

    leaves = [np.zeros((4, 3), np.float32)]
    cond = mp.get_context("spawn").Condition()
    ex0 = create_exchange(leaves, num_ranks=2, cond=cond)
    with pytest.raises(DPError, match="mismatch"):
        attach_exchange(ex0.handle, cond, rank=1,
                        template_leaves=[np.zeros((3, 4), np.float32)])
    with pytest.raises(DPError, match="leaves"):
        attach_exchange(ex0.handle, cond, rank=1,
                        template_leaves=[np.zeros((4, 3), np.float32)] * 2)
    ex0.unlink()


def test_dp_exchange_scalar_leaf_roundtrip():
    """0-d pytree leaves survive the at-least-1-d wire canonicalisation."""
    import jax.numpy as jnp

    from repro.data.dp_trainer import _adopt, _host_leaves

    tree = {"w": jnp.ones((2, 2)), "t": jnp.asarray(3, jnp.int32)}
    host = _host_leaves(tree)
    assert all(h.ndim >= 1 for h in host)
    back = _adopt(tree, host)
    assert back["t"].shape == ()
    assert int(back["t"]) == 3
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones((2, 2)))


# --------------------------------------------------------------------------
# restricted rebuild + DP fit parity
# --------------------------------------------------------------------------


def test_trainer_rebuild_bit_identity():
    """A trainer's deterministic rebuild — config dict round-trip plus the
    attached shared store — reproduces the parent's compiled state, staged
    arrays, and step losses bit for bit (the premise of both DP modes)."""
    from repro.data.dp_trainer import state_sha
    from repro.graph.mmap_store import attach_any
    from repro.graph.shm import share_graph

    parent = _built(_quick_cfg())
    store = share_graph(parent.graph, include_features=True)
    try:
        att = attach_any(store.handle)
        child = Heta(HetaConfig.from_dict(parent.config.to_dict())
                     .updated(pipeline=dict(num_workers=0)))
        child.build_graph(graph=att.graph)
        child.partition()
        child.profile_and_cache()
        child.compile()
        assert state_sha(parent.state) == state_sha(child.state)
        l1 = parent.step()
        l2 = child.step()
        assert float(l1) == float(l2)
        assert state_sha(parent.state) == state_sha(child.state)
        att.close()
    finally:
        store.unlink()


def test_dp_fit_global_bit_identical_to_single():
    """The ISSUE's acceptance: 2-trainer DP fit (stripe discipline) must
    reproduce the single-process loss trajectory bitwise."""
    from repro.graph import mmap_store as ms

    single = _built(_quick_cfg(steps=4))
    single.fit()
    before = set(ms.live_stores())
    dp = _built(_quick_cfg(steps=4, num_trainers=2, mode="global"))
    res = dp.fit()
    assert list(map(float, dp.losses)) == list(map(float, single.losses))
    assert res["scale"]["num_trainers"] == 2
    assert res["scale"]["mode"] == "global"
    # the fit leaked no mmap stores (co-tenant processes may own some)
    assert set(ms.live_stores()) <= before


def test_dp_fit_local_mode_converges_identically_across_trainers():
    """Local mode: hierarchy-owned sub-batches, fixed-rank-order gradient
    reduction.  run_dp_fit itself asserts the cross-trainer loss lists and
    final state hashes match bitwise; here we check it completes and books
    the trajectory."""
    dp = _built(_quick_cfg(steps=3, num_trainers=2, mode="local"))
    res = dp.fit()
    assert res["scale"]["mode"] == "local"
    assert len(dp.losses) == 3
    assert all(np.isfinite(dp.losses))


def test_dp_fit_rejects_learnable_tables():
    from repro.api.session import HetaStageError

    cfg = _quick_cfg(steps=2, num_trainers=2, mode="local")
    cfg = cfg.updated(model=dict(train_learnable=True))
    sess = _built(cfg)
    with pytest.raises(HetaStageError, match="frozen"):
        sess.fit()
