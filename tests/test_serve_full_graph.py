"""Layer-wise full-graph inference (repro.serve.full_graph): per-node
parity against the minibatch raf_spmd forward (the serving tier's Prop-1),
full-graph evaluation, the shm-backed store lifecycle, and the batched
multi-type cache fetch."""

import numpy as np
import pytest

from repro.api import (
    DataConfig,
    Heta,
    HetaConfig,
    HetaStageError,
    KernelConfig,
    ModelConfig,
    RunConfig,
)
from repro.serve import full_graph as fg


def _session(model="rgcn", *, cap=4, steps=0, kernels=None, scale=0.002,
             batch_size=8, seed=0):
    """A trained-or-init session on a degree-capped graph with exhaustive
    fanouts (fanout = max in-degree, so sampling covers every neighbor)."""
    base = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=scale, fanouts=(2, 2),
                        batch_size=batch_size),
        model=ModelConfig(model=model, hidden=16, num_heads=2,
                          learnable_dim=12),
        run=RunConfig(executor="raf_spmd", steps=steps, seed=seed,
                      mesh_shape=(1, 1)),
        kernels=kernels or KernelConfig(enabled=False),
    )
    s0 = Heta(base)
    g = fg.bounded_graph(s0.build_graph(), cap)
    s0.partition()
    ex = fg.exhaustive_fanouts(g, s0.spec)
    sess = Heta(base.updated(data=dict(fanouts=ex)))
    sess.build_graph(g)
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    if steps:
        sess.fit(steps)
    return sess, g


def _parity(sess, g, n_seeds=16):
    tables = sess.engine.tables_snapshot()
    store = fg.infer_all(g, sess.plan.plan, sess.state["stacks"], tables,
                         node_block=64, kernels=sess.config.kernels)
    seeds = g.train_nodes[:n_seeds]
    batch = fg.exhaustive_batch(g, sess.spec, seeds)
    ref = fg.spmd_logits_for_batch(sess.plan.plan, sess.state["stacks"],
                                   batch, tables,
                                   kernels=sess.config.kernels)
    return store, np.asarray(store.scores(seeds)), ref


# --------------------------------------------------------------------------
# Prop-1: layer-wise == minibatch, per node, all three models
# --------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
def test_layerwise_matches_minibatch(model):
    sess, g = _session(model)
    _, got, ref = _parity(sess, g)
    if model in ("rgcn", "rgat"):
        # frozen-feature path with identical reduce structure: bit-equal
        np.testing.assert_array_equal(got, ref)
    else:
        # hgt's per-branch softmax reassociates; well under the 1e-5 bar
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
def test_layerwise_matches_minibatch_interpret_kernels(model):
    """Same parity through the fused Pallas kernels (interpret mode)."""
    sess, g = _session(
        model, kernels=KernelConfig(enabled=True, interpret=True))
    _, got, ref = _parity(sess, g, n_seeds=8)
    if model in ("rgcn", "rgat"):
        np.testing.assert_array_equal(got, ref)
    else:
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


def test_layerwise_matches_minibatch_after_training():
    """Parity holds for trained stacks, not just the init point."""
    sess, g = _session("rgcn", steps=3)
    _, got, ref = _parity(sess, g)
    np.testing.assert_array_equal(got, ref)


def test_store_contents():
    sess, g = _session("rgcn")
    store, _, _ = _parity(sess, g)
    assert store.target_type == g.target_type
    assert store.num_classes == g.num_classes
    for t, emb in store.embeddings.items():
        assert emb.shape == (g.num_nodes[t], sess.config.model.hidden)
        assert emb.dtype == np.float32
    # the target type reaches the top layer
    assert store.layer_of[g.target_type] == sess.spec.num_layers
    # embedding() slices rows; scores() applies relu + head
    nids = g.train_nodes[:4]
    emb = store.embedding(g.target_type, nids)
    want = np.maximum(emb, 0.0) @ store.head["w"] + store.head["b"]
    np.testing.assert_allclose(store.scores(nids), want, atol=1e-6)


# --------------------------------------------------------------------------
# exhaustive-neighborhood helpers
# --------------------------------------------------------------------------


def test_bounded_graph_caps_degree():
    s0 = Heta(HetaConfig(data=DataConfig(scale=0.002)))
    g = s0.build_graph()
    capped = fg.bounded_graph(g, 4)
    for rel, csr in capped.relations.items():
        deg = csr.indptr[1:] - csr.indptr[:-1]
        assert deg.max(initial=0) <= 4
        # kept neighbors are a prefix of the original CSR lists
        orig = g.relations[rel]
        v = int(np.argmax(orig.indptr[1:] - orig.indptr[:-1]))
        np.testing.assert_array_equal(
            csr.indices[csr.indptr[v]:csr.indptr[v + 1]],
            orig.indices[orig.indptr[v]:orig.indptr[v] + deg[v]],
        )


def test_exhaustive_fanouts_guard():
    """_full_neighbors refuses a fanout below the max in-degree."""
    sess, g = _session("rgcn")
    small = tuple(max(1, f - 1) for f in sess.spec.fanouts)
    if small == sess.spec.fanouts:
        pytest.skip("degenerate graph: fanouts already 1")
    spec = sess.spec
    rel = spec.levels[0][0].rel
    csr = g.relations[rel]
    deg = csr.indptr[1:] - csr.indptr[:-1]
    parents = np.array([int(np.argmax(deg))])
    with pytest.raises(ValueError, match="max in-degree"):
        fg._full_neighbors(csr, parents, np.ones(1, bool),
                           int(deg.max()) - 1)


# --------------------------------------------------------------------------
# full-graph evaluation
# --------------------------------------------------------------------------


def test_evaluate_full_graph_matches_minibatch():
    """On a degree-<=1 graph with fanout 1 the with-replacement sampler is
    forced onto each node's unique neighbor, so the sampled eval forward
    sees exactly the full neighborhoods and the two paths agree."""
    sess, g = _session("rgcn", steps=2, cap=1)
    sess.infer_all(node_block=64)
    ref = sess.evaluate(num_batches=2)
    got = sess.evaluate(num_batches=2, use_full_graph=True)
    assert got["full_graph"] is True
    assert got["num_batches"] == ref["num_batches"]
    np.testing.assert_allclose(got["loss"], ref["loss"], atol=1e-5)


def test_evaluate_full_graph_requires_infer_all():
    sess, _ = _session("rgcn")
    with pytest.raises(HetaStageError, match="infer_all"):
        sess.evaluate(use_full_graph=True)


def test_infer_all_requires_stacked_plan():
    sess, _ = _session("rgcn")
    sess.compile(executor="vanilla")
    with pytest.raises(HetaStageError, match="raf_spmd"):
        sess.infer_all()


# --------------------------------------------------------------------------
# shm-backed store lifecycle
# --------------------------------------------------------------------------


def test_shm_store_attach_and_close():
    import os

    if not os.path.isdir("/dev/shm"):
        pytest.skip("shm store needs /dev/shm")
    from repro.graph.shm import live_segments

    before = set(live_segments())
    sess, g = _session("rgcn")
    store = fg.infer_all(g, sess.plan.plan, sess.state["stacks"],
                         sess.engine.tables_snapshot(), node_block=64,
                         kernels=sess.config.kernels, shm=True)
    assert store.handle is not None
    nids = g.train_nodes[:4]
    want = store.scores(nids)
    # a second store attaches zero-copy and reads identical values
    attached = fg.EmbeddingStore.attach(store.handle)
    assert sorted(attached.embeddings) == sorted(store.embeddings)
    assert attached.layer_of == store.layer_of
    np.testing.assert_array_equal(attached.scores(nids), want)
    attached.close()
    attached.close()  # idempotent
    store.close()
    store.close()
    assert set(live_segments()) == before


# --------------------------------------------------------------------------
# FeatureCache.fetch_many
# --------------------------------------------------------------------------


def test_fetch_many_matches_fetch():
    from repro.embed.cache import CacheAllocation, FeatureCache
    from repro.embed.profiler import HotnessProfile

    rng = np.random.default_rng(0)
    tables = {"a": rng.normal(size=(40, 8)).astype(np.float32),
              "b": rng.normal(size=(30, 8)).astype(np.float32)}
    hot = HotnessProfile(counts={t: np.ones(v.shape[0]) for t, v in tables.items()})
    alloc = CacheAllocation(rows={"a": 10, "b": 0},
                            bytes_={"a": 10 * 32, "b": 0},
                            total_bytes=10 * 32, policy="test")
    cache = FeatureCache(tables, {}, alloc, hot)
    reqs = {"a": np.array([3, 1, 11]), "b": np.array([0, 29])}
    out = cache.fetch_many(reqs)
    assert sorted(out) == ["a", "b"]
    for t, nids in reqs.items():
        np.testing.assert_array_equal(np.asarray(out[t]), tables[t][nids])
    # empty requests produce no entry (no zero-length device gathers)
    out2 = cache.fetch_many({"a": np.array([], np.int64), "b": np.array([2])})
    assert sorted(out2) == ["b"]
    # counters accrue exactly as per-type fetch calls would
    cache.reset_stats()
    cache.fetch_many({"a": np.array([3, 1, 11])})
    c = cache.caches["a"]
    assert c.hits + c.misses == 3
