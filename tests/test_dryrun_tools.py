"""Unit tests for the dry-run tooling: HLO collective parser, extrapolation,
plan logic, and the roofline math (no 512-device environment needed)."""

import sys
import os

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _import_dryrun_tools():
    """Import parser/extrapolator without triggering the module's XLA_FLAGS
    512-device override (jax is already initialized by other tests)."""
    import importlib

    saved = os.environ.get("XLA_FLAGS")
    mod = importlib.import_module("repro.launch.dryrun")
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    return mod


HLO = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %ag = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %aa = f32[8,8]{1,0} all-to-all(%z), dimensions={0}
  %cp = s32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %rs = f32[2,64]{1,0} reduce-scatter(%v), dimensions={0}, to_apply=%sum
  %dead = f32[999,999]{1,0} add(%a, %b)
"""


def test_collective_parser():
    dr = _import_dryrun_tools()
    got = dr.collective_bytes(HLO)
    assert got["all-reduce"] == 16 * 128 * 4
    assert got["all-gather"] == 4 * 256 * 2
    assert got["all-to-all"] == 8 * 8 * 4
    assert got["collective-permute"] == 10 * 4
    assert got["reduce-scatter"] == 2 * 64 * 4
    assert got["count_all-reduce"] == 1
    expected_total = 16 * 128 * 4 + 4 * 256 * 2 + 8 * 8 * 4 + 40 + 2 * 64 * 4
    assert got["total"] == expected_total


def test_extrapolation_linear_and_clamped():
    dr = _import_dryrun_tools()
    r1 = {"flops": 10.0, "bytes_accessed": 100.0, "transcendentals": 1.0,
          "collectives": {"all-reduce": 8, "total": 8}}
    r2 = {"flops": 16.0, "bytes_accessed": 150.0, "transcendentals": 1.5,
          "collectives": {"all-reduce": 12, "total": 12}}
    out = dr._extrapolate(r1, r2, 10)
    assert out["flops"] == 10 + 9 * 6  # f(1) + (n-1)·delta
    assert out["collectives"]["all-reduce"] == 8 + 9 * 4
    # non-monotone counters clamp at ≥ f(2), never negative
    r2b = dict(r2, flops=9.0)
    out2 = dr._extrapolate(r1, r2b, 10)
    assert out2["flops"] == 10.0  # max(r1 + 0, r2)


def test_roofline_math():
    from benchmarks.roofline import roofline_row

    rec = {
        "status": "ok", "arch": "x", "shape": "train_4k", "mesh": "pod16x16",
        "step_kind": "train", "num_devices": 256,
        "active_params": 1e9,
        "flops": 197e12,  # exactly one second of compute
        "bytes_accessed": 819e9,  # one second of HBM
        "collectives": {"total": 100e9},  # two seconds of ICI
        "memory": {},
    }
    row = roofline_row(rec)
    assert row["compute_s"] == pytest.approx(1.0)
    assert row["memory_s"] == pytest.approx(1.0)
    assert row["collective_s"] == pytest.approx(2.0)
    assert row["dominant"] == "collective"
    # 6·N·T / (flops × devices)
    from repro.configs.base import INPUT_SHAPES

    t = INPUT_SHAPES["train_4k"].tokens
    assert row["useful_ratio"] == pytest.approx(6 * 1e9 * t / (197e12 * 256))


def test_plan_windows_and_cache_lengths():
    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS, INPUT_SHAPES
    from repro.launch.specs import DENSE_WINDOW, plan_step

    for name, cfg in ARCHS.items():
        for sh in INPUT_SHAPES.values():
            p = plan_step(cfg, sh)
            if p.kind == "skip":
                assert not cfg.is_decoder
                continue
            if sh.kind == "decode":
                if sh.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
                    assert p.window == DENSE_WINDOW
                    assert p.cache_len == DENSE_WINDOW
                else:
                    assert p.window is None
                    assert p.cache_len == sh.seq_len
