"""Expert-parallel MoE (§Perf pair 1): equivalence with the GSPMD baseline.

Single-shard: bit-exact.  Multi-shard (subprocess, 8 devices): exact at
ample capacity; at tight capacity the per-shard (GShard-style) groups drop
different tokens than global routing — verified bounded, not silent.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs.all_archs  # noqa: F401
from repro.configs.base import ARCHS
from repro.models.moe import moe_block, moe_block_ep, moe_params

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_ep_single_shard_exact():
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    rng = np.random.default_rng(0)
    p = moe_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ref = moe_block(p, cfg, x)
    out = moe_block_ep(p, cfg, x, mesh, ("data",))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_ep_grad_flows():
    cfg = dataclasses.replace(
        ARCHS["granite-moe-1b-a400m"].reduced(), capacity_factor=32.0
    )
    rng = np.random.default_rng(1)
    p = moe_params(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    g = jax.grad(lambda pp: jnp.sum(moe_block_ep(pp, cfg, x, mesh, ("data",)) ** 2))(p)
    gref = jax.grad(lambda pp: jnp.sum(moe_block(pp, cfg, x) ** 2))(p)
    for k in ("w1", "w2", "w3", "router"):
        np.testing.assert_allclose(
            np.asarray(g[k]), np.asarray(gref[k]), atol=1e-4, err_msg=k
        )
    assert float(jnp.abs(g["w1"]).max()) > 0


@pytest.mark.slow
def test_ep_multidevice_matches_at_ample_capacity():
    code = r"""
import dataclasses, json
import numpy as np, jax, jax.numpy as jnp
import repro.configs.all_archs
from repro.configs.base import ARCHS
from repro.models.moe import moe_block, moe_block_ep, moe_params

cfg = dataclasses.replace(ARCHS["qwen3-moe-30b-a3b"].reduced(), capacity_factor=64.0)
rng = np.random.default_rng(0)
p = moe_params(jax.random.PRNGKey(1), cfg, jnp.float32)
x = jnp.asarray(rng.standard_normal((4, 64, cfg.d_model)), jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ref = moe_block(p, cfg, x)
out = jax.jit(lambda p_, x_: moe_block_ep(p_, cfg, x_, mesh, ("data",)))(p, x)
d = float(jnp.abs(out - ref).max())
print(json.dumps({"maxdiff": d}))
assert d < 1e-4, d
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["maxdiff"] < 1e-4
