"""Public API tests: HetaConfig validation + round-trips, session stage
ordering, executor registry, and cross-executor loss parity through the
uniform protocol (ISSUE 1 acceptance)."""

import argparse

import numpy as np
import pytest

from repro.api import (
    CacheConfig,
    DataConfig,
    Heta,
    HetaConfig,
    HetaStageError,
    ModelConfig,
    PartitionConfig,
    RunConfig,
    add_config_args,
    config_from_args,
    executors,
)
from repro.launch.train import train_hgnn


def tiny_config(executor="raf_spmd", **run_kw):
    return HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                        batch_size=16),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=32),
        cache=CacheConfig(cache_mb=2),
        run=RunConfig(executor=executor, steps=3, lr=1e-2, seed=0, **run_kw),
    )


# --------------------------------------------------------------------------
# config validation + round-trips
# --------------------------------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="model"):
        ModelConfig(model="gcn")
    with pytest.raises(ValueError, match="placement"):
        PartitionConfig(placement="randomly")
    with pytest.raises(ValueError, match="fanouts"):
        DataConfig(fanouts=())
    with pytest.raises(ValueError, match="mesh_shape"):
        RunConfig(mesh_shape=(0, 1))
    with pytest.raises(ValueError, match="policy"):
        CacheConfig(policy="lru")
    with pytest.raises(ValueError, match="divisible"):
        ModelConfig(hidden=30, num_heads=4)


def test_config_defaults_match_legacy_train_hgnn():
    """The config tree's defaults ARE the legacy kwargs-blob defaults."""
    cfg = HetaConfig()
    assert cfg.data.dataset == "ogbn-mag" and cfg.data.fanouts == (4, 3)
    assert cfg.partition.num_partitions == 4 and cfg.partition.placement == "meta"
    assert cfg.run.steps == 20 and cfg.run.lr == 5e-3
    assert cfg.cache.cache_mb == 4 and not cfg.cache.hotness_only


def test_flat_kwargs_round_trip():
    cfg = HetaConfig.from_flat_kwargs(
        dataset="freebase", scale=0.001, model="rgat", num_partitions=3,
        mesh_shape=(1, 2), batch_size=8, fanouts=(3, 2), hidden=32, steps=4,
        lr=1e-2, cache_mb=2, hotness_only=True, naive_placement=True,
        learnable_dim=16, seed=3, log_every=2, executor="raf",
    )
    assert cfg.partition.placement == "naive"
    assert cfg.cache.policy == "hotness"
    assert cfg.data.fanouts == (3, 2) and cfg.run.mesh_shape == (1, 2)
    assert HetaConfig.from_flat_kwargs(**cfg.to_flat_kwargs()) == cfg
    with pytest.raises(TypeError, match="unknown train_hgnn kwarg"):
        HetaConfig.from_flat_kwargs(batchsize=8)


def test_dict_round_trip():
    cfg = tiny_config("raf")
    d = cfg.to_dict()
    assert d["run"]["executor"] == "raf"
    assert isinstance(d["data"]["fanouts"], list)  # JSON-friendly
    assert HetaConfig.from_dict(d) == cfg
    with pytest.raises(TypeError, match="unknown"):
        HetaConfig.from_dict({"data": {"nope": 1}})


def test_cli_round_trip():
    """CLI flags are derived from the config fields; parsing them back
    reproduces the config."""
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    args = ap.parse_args([
        "--dataset", "freebase", "--fanouts", "3,2", "--mesh", "1x2",
        "--partitions", "3", "--placement", "naive", "--hidden", "32",
        "--cache-policy", "hotness", "--executor", "raf", "--steps", "4",
    ])
    cfg = config_from_args(args)
    assert cfg.data.dataset == "freebase" and cfg.data.fanouts == (3, 2)
    assert cfg.run.mesh_shape == (1, 2) and cfg.run.executor == "raf"
    assert cfg.partition.num_partitions == 3
    assert cfg.partition.placement == "naive"
    assert cfg.cache.policy == "hotness" and cfg.run.steps == 4
    # unset flags keep defaults
    assert cfg.data.batch_size == DataConfig().batch_size


def test_updated_rejects_unknown_sections_and_fields():
    cfg = HetaConfig()
    with pytest.raises(TypeError):
        cfg.updated(runn=dict(steps=2))
    with pytest.raises(TypeError):
        cfg.updated(run=dict(stepss=2))
    assert cfg.with_executor("raf").run.executor == "raf"


# --------------------------------------------------------------------------
# executor registry
# --------------------------------------------------------------------------


def test_registry_lookup():
    assert set(executors.available()) >= {"vanilla", "raf", "raf_spmd"}
    for name in ("vanilla", "raf", "raf_spmd"):
        assert executors.get(name).name == name
    with pytest.raises(KeyError, match="raf_spmd"):  # lists what IS available
        executors.get("bogus_executor")


def test_register_custom_executor():
    @executors.register("_test_dummy")
    class Dummy(executors.Executor):
        pass

    try:
        assert "_test_dummy" in executors.available()
        assert isinstance(executors.get("_test_dummy"), Dummy)
    finally:
        del executors._REGISTRY["_test_dummy"]


# --------------------------------------------------------------------------
# session lifecycle
# --------------------------------------------------------------------------


def test_stage_ordering_errors():
    sess = Heta(tiny_config())
    with pytest.raises(HetaStageError, match="compile"):
        sess.fit()
    with pytest.raises(HetaStageError, match="build_graph"):
        sess.partition()
    sess.build_graph()
    with pytest.raises(HetaStageError, match="profile_and_cache"):
        sess.compile()
    part = sess.partition()
    assert part.meta_local and part.num_partitions == 2
    with pytest.raises(HetaStageError, match="compile"):
        sess.step()


def test_stagewise_equals_run():
    """Stage-by-stage execution and the run() convenience are equivalent."""
    sess = Heta(tiny_config())
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    m1 = sess.fit()
    m2 = Heta(tiny_config()).run()
    np.testing.assert_allclose(m1["losses"], m2["losses"], rtol=0, atol=0)


def test_unknown_executor_at_compile():
    sess = Heta(tiny_config(executor="not_an_executor"))
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    with pytest.raises(KeyError, match="available"):
        sess.compile()


def test_partition_report_comm_accounting():
    sess = Heta(tiny_config())
    sess.build_graph()
    part = sess.partition()
    comm = sess.comm_report(bytes_per_elem=2)
    # meta placement: exactly the Θ(B·hidden) root exchange (Prop 2)
    assert comm["raf_meta"] == part.raf_bytes(16, 32, 2)
    assert comm["raf_meta"] <= comm["raf_naive"]


def test_evaluate_no_update():
    sess = Heta(tiny_config("vanilla"))
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    e1 = sess.evaluate()
    e2 = sess.evaluate()
    assert np.isfinite(e1["loss"]) and e1["loss"] == e2["loss"]  # no training
    assert sess.losses == []


# --------------------------------------------------------------------------
# executor parity through the uniform protocol (acceptance criteria)
# --------------------------------------------------------------------------


def _losses(executor):
    return np.asarray(Heta(tiny_config(executor)).run()["losses"])


def test_parity_vanilla_vs_raf():
    """Prop 1, trained: the simulated RAF executor follows the vanilla loss
    curve step-for-step (identical seeds -> identical params and batches)."""
    lv, lr_ = _losses("vanilla"), _losses("raf")
    np.testing.assert_allclose(lv, lr_, atol=1e-5)


def test_parity_raf_vs_raf_spmd():
    """The production SPMD executor trains the same model as the simulated
    one (stacked/padded representation + sparse cache updates)."""
    lr_, ls = _losses("raf"), _losses("raf_spmd")
    assert np.all(np.isfinite(ls))
    np.testing.assert_allclose(lr_, ls, atol=5e-3)


# --------------------------------------------------------------------------
# the deprecated wrapper
# --------------------------------------------------------------------------


def test_train_hgnn_wrapper_result_keys():
    m = train_hgnn(dataset="ogbn-mag", scale=0.002, model="rgcn",
                   num_partitions=2, batch_size=16, fanouts=(3, 2), steps=2,
                   cache_mb=2)
    for key in ("losses", "step_time_s", "hit_rates", "partitioning",
                "meta_local", "cache_allocation"):
        assert key in m, key
    assert len(m["losses"]) == 2 and m["meta_local"]


# --------------------------------------------------------------------------
# async host pipeline (ISSUE 2 acceptance): parity with the serial path
# --------------------------------------------------------------------------


def _pipe_config(executor, train_learnable=True, **pipeline):
    cfg = tiny_config(executor)
    if not train_learnable:
        cfg = cfg.updated(model=dict(train_learnable=False))
    return cfg.updated(pipeline=dict(enabled=True, **pipeline))


@pytest.mark.parametrize("executor", ["vanilla", "raf", "raf_spmd"])
def test_pipeline_parity_frozen_features(executor):
    """With frozen feature tables, staging is time-invariant: pipeline on/off
    must produce bit-identical losses for every executor."""
    off = Heta(tiny_config(executor).updated(
        model=dict(train_learnable=False))).run()
    on = Heta(_pipe_config(executor, train_learnable=False)).run()
    assert off["losses"] == on["losses"]  # bit-identical
    assert on["pipeline"] and not off["pipeline"]
    assert "overlap_fraction" in on and on["overlap_fraction"] >= 0.0


@pytest.mark.parametrize("executor", ["vanilla", "raf"])
def test_pipeline_parity_learnable_dense_executors(executor):
    """Dense executors carry learnable rows in the parameter bundle — their
    staging never reads tables, so even learnable training is bit-exact."""
    off = Heta(tiny_config(executor)).run()
    on = Heta(_pipe_config(executor)).run()
    assert off["losses"] == on["losses"]


def test_pipeline_learnable_spmd_stale_within_tolerance():
    """raf_spmd staging snapshots learnable tables; under the default
    "stale" policy background staging may lag by <= depth+1 steps, so
    losses track the serial path within optimization noise."""
    off = Heta(tiny_config("raf_spmd")).run()
    on = Heta(_pipe_config("raf_spmd")).run()
    np.testing.assert_allclose(off["losses"], on["losses"], atol=5e-2)


def test_pipeline_learnable_spmd_fresh_is_bit_exact():
    """The "fresh" snapshot policy defers table-reading staging to the
    consumer -> bit-exact parity even while learnable tables train."""
    off = Heta(tiny_config("raf_spmd")).run()
    on = Heta(_pipe_config("raf_spmd", snapshot="fresh")).run()
    assert off["losses"] == on["losses"]


def test_pipeline_evaluate_parity():
    s_off = Heta(tiny_config("vanilla").updated(model=dict(train_learnable=False)))
    s_on = Heta(_pipe_config("vanilla", train_learnable=False))
    s_off.run(), s_on.run()
    assert s_off.evaluate(3) == s_on.evaluate(3)


def test_kernel_config_round_trips():
    cfg = HetaConfig().updated(kernels=dict(enabled=False, stacked_agg=False,
                                            interpret=True))
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    assert HetaConfig.from_flat_kwargs(**cfg.to_flat_kwargs()) == cfg
    with pytest.raises(ValueError, match="kernels.enabled"):
        HetaConfig().updated(kernels=dict(enabled="yes"))
    with pytest.raises(ValueError, match="interpret"):
        HetaConfig().updated(kernels=dict(interpret="auto"))
    # derived CLI flags (tri-state interpret: absent -> None)
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    got = config_from_args(ap.parse_args(
        ["--no-kernels", "--kernel-interpret", "--no-kernel-gather"]))
    assert not got.kernels.enabled and got.kernels.interpret is True
    assert not got.kernels.gather and got.kernels.stacked_agg
    assert config_from_args(ap.parse_args([])).kernels.interpret is None


def test_kernel_block_config_round_trips():
    """The block-size knobs (autotune + explicit overrides) round-trip
    through dict / flat-kwargs / CLI, and non-positive blocks are rejected."""
    cfg = HetaConfig().updated(kernels=dict(autotune=True, block_n=64,
                                            block_in=256, fuse_epilogue=False))
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    flat = cfg.to_flat_kwargs()
    assert flat["kernel_autotune"] is True
    assert flat["kernel_block_n"] == 64 and flat["kernel_block_out"] is None
    assert HetaConfig.from_flat_kwargs(**flat) == cfg
    for f in ("block_n", "block_out", "block_in"):
        for bad in (0, -8, 1.5, True):
            with pytest.raises(ValueError, match=f"kernels.{f}"):
                HetaConfig().updated(kernels={f: bad})
    with pytest.raises(ValueError, match="kernels.autotune"):
        HetaConfig().updated(kernels=dict(autotune="yes"))
    with pytest.raises(ValueError, match="kernels.fuse_epilogue"):
        HetaConfig().updated(kernels=dict(fuse_epilogue=1.0))
    # derived CLI flags (unset blocks stay None -> dispatch defaults)
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    got = config_from_args(ap.parse_args(
        ["--kernel-autotune", "--kernel-block-n", "256",
         "--no-kernel-fuse-epilogue"]))
    assert got.kernels.autotune and got.kernels.block_n == 256
    assert got.kernels.fuse_epilogue is False
    base = config_from_args(ap.parse_args([]))
    assert base.kernels.block_n is None and base.kernels.autotune is False
    assert base.kernels.fuse_epilogue is True


def test_cache_readmit_config_round_trips():
    cfg = HetaConfig().updated(cache=dict(readmit_every=2),
                               serve=dict(readmit_every=5))
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    assert HetaConfig.from_flat_kwargs(**cfg.to_flat_kwargs()) == cfg
    with pytest.raises(ValueError, match="readmit_every"):
        HetaConfig().updated(cache=dict(readmit_every=-1))
    with pytest.raises(ValueError, match="readmit_every"):
        HetaConfig().updated(serve=dict(readmit_every=-2))
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    got = config_from_args(ap.parse_args(
        ["--readmit-every", "3", "--serve-readmit-every", "7"]))
    assert got.cache.readmit_every == 3 and got.serve.readmit_every == 7
    assert config_from_args(ap.parse_args([])).cache.readmit_every == 0


def test_fit_loop_triggers_online_readmission():
    """cache.readmit_every wires EmbedEngine.rebalance into the fit loop:
    4 steps at period 2 -> exactly 2 rebalances, and training still runs."""
    sess = Heta(tiny_config().updated(cache=dict(readmit_every=2),
                                      run=dict(steps=4)))
    m = sess.run()
    assert sess.engine.rebalances == 2
    assert sess.engine.stats()["rebalances"] == 2
    assert len(m["losses"]) == 4
    assert np.isfinite(m["losses"]).all()


def test_pipeline_config_round_trips():
    cfg = HetaConfig().updated(pipeline=dict(enabled=True, depth=3,
                                             snapshot="fresh", num_workers=4))
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    assert HetaConfig.from_flat_kwargs(**cfg.to_flat_kwargs()) == cfg
    with pytest.raises(ValueError, match="snapshot"):
        HetaConfig().updated(pipeline=dict(snapshot="psychic"))
    with pytest.raises(ValueError, match="depth"):
        HetaConfig().updated(pipeline=dict(depth=0))
    with pytest.raises(ValueError, match="num_workers"):
        HetaConfig().updated(pipeline=dict(num_workers=-1))
    # derived CLI flags
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    args = ap.parse_args(["--pipeline", "--prefetch-depth", "4",
                          "--snapshot-policy", "fresh", "--num-workers", "2"])
    got = config_from_args(args)
    assert got.pipeline.enabled and got.pipeline.depth == 4
    assert got.pipeline.snapshot == "fresh"
    assert got.pipeline.num_workers == 2
    assert config_from_args(ap.parse_args([])).pipeline.num_workers == 0


def test_checkpoint_and_fault_config_round_trips():
    cfg = HetaConfig().updated(
        checkpoint=dict(every_steps=5, dir="/tmp/ck", keep=3),
        faults=dict(max_worker_restarts=4, worker_backoff_s=0.1,
                    arena_write_timeout_s=12.0),
        serve=dict(deadline_ms=250.0, flush_retries=1, retry_backoff_ms=0.5,
                   breaker_threshold=2, breaker_cooldown_ms=100.0),
    )
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    flat = cfg.to_flat_kwargs()
    assert flat["checkpoint_every_steps"] == 5
    assert flat["max_worker_restarts"] == 4
    assert flat["serve_breaker_threshold"] == 2
    assert HetaConfig.from_flat_kwargs(**flat) == cfg

    with pytest.raises(ValueError, match="every_steps"):
        HetaConfig().updated(checkpoint=dict(every_steps=-1))
    with pytest.raises(ValueError, match="checkpoint.dir"):
        HetaConfig().updated(checkpoint=dict(every_steps=2))
    with pytest.raises(ValueError, match="max_worker_restarts"):
        HetaConfig().updated(faults=dict(max_worker_restarts=-1))
    with pytest.raises(ValueError, match="breaker_threshold"):
        HetaConfig().updated(serve=dict(breaker_threshold=0))
    with pytest.raises(ValueError, match="deadline_ms"):
        HetaConfig().updated(serve=dict(deadline_ms=-1.0))

    ap = argparse.ArgumentParser()
    add_config_args(ap)
    args = ap.parse_args([
        "--checkpoint-every-steps", "2", "--checkpoint-dir", "/tmp/ck",
        "--checkpoint-keep", "1", "--max-worker-restarts", "3",
        "--worker-backoff-s", "0.2", "--serve-deadline-ms", "100",
        "--serve-flush-retries", "1", "--serve-breaker-threshold", "5",
    ])
    got = config_from_args(args)
    assert got.checkpoint.every_steps == 2 and got.checkpoint.dir == "/tmp/ck"
    assert got.checkpoint.keep == 1
    assert got.faults.max_worker_restarts == 3
    assert got.faults.worker_backoff_s == 0.2
    assert got.serve.deadline_ms == 100.0
    assert got.serve.flush_retries == 1
    assert got.serve.breaker_threshold == 5


def test_legacy_step_only_executor_still_works():
    """Executors registered before the staged-step seam (override step()
    only) keep working on the serial path; the pipeline names them as the
    reason it can't run."""

    @executors.register("_test_legacy")
    class Legacy(executors.Executor):
        def build_plan(self, sess):
            return executors.get("vanilla").build_plan(sess)

        def init_state(self, sess, plan):
            return executors.get("vanilla").init_state(sess, plan)

        def step(self, sess, plan, state, batch):
            return executors.get("vanilla").step(sess, plan, state, batch)

        def loss_and_metrics(self, sess, plan, state, batch):
            return executors.get("vanilla").loss_and_metrics(
                sess, plan, state, batch)

    try:
        m = Heta(tiny_config("_test_legacy")).run()
        assert len(m["losses"]) == 3 and np.all(np.isfinite(m["losses"]))
        sess = Heta(tiny_config("_test_legacy").updated(
            pipeline=dict(enabled=True)))
        with pytest.raises(HetaStageError, match="staged-step"):
            sess.run()
    finally:
        del executors._REGISTRY["_test_legacy"]
