"""Stacked relation-aggregation kernel family: parity sweeps against the
gather-then-vmap oracle (interpret mode on CPU; TPU is the target).

Covers forward AND custom-VJP parity over non-block-multiple shapes,
all-False mask rows, dummy padding slots, shared stack rows (the HGT
pattern), the grouped "stacked XLA" oracle, the executor-level fused-path
contract (rgcn bit-equality, DESIGN.md §8) and a hypothesis-style property
test through the ``_hypothesis_compat`` shim.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.relmod import ShapeCtx, get_relation_module
from repro.kernels.ops import KernelOptions
from repro.kernels.stacked_relation_agg import (
    stacked_agg,
    stacked_agg_grouped,
    stacked_agg_ref,
    stacked_mean_linear,
    stacked_mean_linear_vmem_bytes,
    stacked_softmax_combine,
)

rng = np.random.default_rng(7)
OPTS_ON = KernelOptions(interpret=True)


def _mean_linear_case(rb, n, f, di, do, U, seed=0, dummy_slots=()):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((U, di, do)) * 0.1, jnp.float32)
    b = jnp.asarray(r.standard_normal((U, do)) * 0.1, jnp.float32)
    h = jnp.asarray(r.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(r.standard_normal((rb, n, di)), jnp.float32)
    mask = np.asarray(r.random((rb, n, f)) > 0.3)
    mask[0, 0, :] = False  # an all-False row (empty neighborhood)
    for s in dummy_slots:  # dummy padding slots: all-False masks, slot_u 0
        mask[s] = False
    slot_u = r.integers(0, U, rb)
    slot_u[list(dummy_slots)] = 0
    return h, q, jnp.asarray(mask), w, b, jnp.asarray(slot_u)


# --------------------------------------------------------------------------
# mean_linear (rgcn family)
# --------------------------------------------------------------------------

# non-block-multiple n/f/rb/d on purpose: padding paths must be exact
ML_SHAPES = [
    (5, 17, 4, 37, 24, 3),     # tiny/ragged everywhere
    (1, 1, 1, 1, 1, 1),        # degenerate minimum
    (8, 130, 3, 129, 65, 8),   # one past the n/d_out block edges
    (12, 64, 25, 128, 64, 6),  # mag-ish, shared slots (U < rb)
]


@pytest.mark.parametrize("rb,n,f,di,do,U", ML_SHAPES)
def test_stacked_mean_linear_forward_bit_equal(rb, n, f, di, do, U):
    mod = get_relation_module("rgcn")
    h, q, mask, w, b, slot_u = _mean_linear_case(rb, n, f, di, do, U, seed=rb * n)
    ref = stacked_agg_ref(mod, {"w": w, "b": b}, {"relation": slot_u}, h, q, mask)
    out = stacked_mean_linear(h, mask, w, b, slot_u, interpret=True)
    # fp32 interpret mode is bit-equal to the vmap oracle — the acceptance
    # contract of the fused path, not merely close (holds whenever d_in
    # fits one chunk, i.e. every sampled feature/hidden width ≤ block_in)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_stacked_mean_linear_forward_chunked_d_in():
    """d_in wider than block_in (donor's 789-wide features) splits the
    contraction across VMEM accumulator chunks — fp32 reassociation, so
    close (not bit-equal) to the single-matmul oracle."""
    mod = get_relation_module("rgcn")
    rb, n, f, di, do, U = 3, 200, 7, 789, 349, 2
    h, q, mask, w, b, slot_u = _mean_linear_case(rb, n, f, di, do, U, seed=600)
    ref = stacked_agg_ref(mod, {"w": w, "b": b}, {"relation": slot_u}, h, q, mask)
    out = stacked_mean_linear(h, mask, w, b, slot_u, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("dummy_slots", [(), (1, 3)])
def test_stacked_mean_linear_vjp_matches_oracle(dummy_slots):
    mod = get_relation_module("rgcn")
    rb, n, f, di, do, U = 6, 33, 5, 40, 28, 3  # shared rows: U < rb
    h, q, mask, w, b, slot_u = _mean_linear_case(
        rb, n, f, di, do, U, seed=11, dummy_slots=dummy_slots
    )
    valid = jnp.asarray([s not in dummy_slots for s in range(rb)], jnp.float32)

    def loss_fused(w_, b_, h_):
        out = stacked_mean_linear(h_, mask, w_, b_, slot_u, interpret=True)
        return jnp.sum((out * valid[:, None, None]) ** 2)

    def loss_ref(w_, b_, h_):
        out = stacked_agg_ref(mod, {"w": w_, "b": b_}, {"relation": slot_u},
                              h_, q, mask)
        return jnp.sum((out * valid[:, None, None]) ** 2)

    gf = jax.grad(loss_fused, argnums=(0, 1, 2))(w, b, h)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(w, b, h)
    for name, a, c in zip(("dw", "db", "dh"), gf, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(c), atol=1e-5, rtol=1e-5,
            err_msg=f"{name} mismatch (dummy_slots={dummy_slots})",
        )


def test_stacked_mean_linear_grad_lands_in_stack_rows():
    """Slots sharing a stack row sum their contributions into that one row
    (the custom VJP's segment-sum), and unused rows get exactly zero."""
    rb, n, f, di, do, U = 4, 9, 3, 12, 8, 3
    h, q, mask, w, b, _ = _mean_linear_case(rb, n, f, di, do, U, seed=5)
    slot_u = jnp.asarray([0, 0, 1, 1])  # row 2 unused

    def loss(w_):
        return jnp.sum(stacked_mean_linear(h, mask, w_, b, slot_u, interpret=True))

    dw = jax.grad(loss)(w)
    np.testing.assert_array_equal(np.asarray(dw[2]), np.zeros((di, do), np.float32))
    assert float(jnp.abs(dw[0]).max()) > 0 and float(jnp.abs(dw[1]).max()) > 0


@given(
    rb=st.integers(1, 6), n=st.integers(1, 40), f=st.integers(1, 6),
    di=st.integers(1, 70), do=st.integers(1, 70), U=st.integers(1, 4),
)
@settings(max_examples=10, deadline=None)
def test_stacked_mean_linear_property(rb, n, f, di, do, U):
    mod = get_relation_module("rgcn")
    h, q, mask, w, b, slot_u = _mean_linear_case(
        rb, n, f, di, do, U, seed=rb * 1000 + n * 10 + di
    )
    ref = stacked_agg_ref(mod, {"w": w, "b": b}, {"relation": slot_u}, h, q, mask)
    out = stacked_mean_linear(h, mask, w, b, slot_u, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# softmax_combine epilogue (rgat/hgt family)
# --------------------------------------------------------------------------


def _attn_case(rb, n, f, nh, dh, seed=0):
    r = np.random.default_rng(seed)
    e = jnp.asarray(r.standard_normal((rb, n, f, nh)), jnp.float32)
    v = jnp.asarray(r.standard_normal((rb, n, f, nh, dh)), jnp.float32)
    mask = np.asarray(r.random((rb, n, f)) > 0.3)
    mask[0, 0, :] = False
    return e, jnp.asarray(mask), v


@pytest.mark.parametrize("rb,n,f,nh,dh", [
    (3, 21, 4, 2, 5),
    (1, 1, 1, 1, 1),
    (5, 130, 3, 4, 16),
])
def test_stacked_softmax_combine_parity(rb, n, f, nh, dh):
    from repro.core.relmod import masked_softmax

    e, mask, v = _attn_case(rb, n, f, nh, dh, seed=n)
    alpha = masked_softmax(e, mask[:, :, :, None], axis=2)
    ref = jnp.einsum("rnfh,rnfhd->rnhd", alpha, v).reshape(rb, n, nh * dh)
    out = stacked_softmax_combine(e, mask, v, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6, rtol=1e-6)

    def loss_fused(e_, v_):
        return jnp.sum(stacked_softmax_combine(e_, mask, v_, interpret=True) ** 2)

    def loss_ref(e_, v_):
        a = masked_softmax(e_, mask[:, :, :, None], axis=2)
        return jnp.sum(jnp.einsum("rnfh,rnfhd->rnhd", a, v_) ** 2)

    gf = jax.grad(loss_fused, argnums=(0, 1))(e, v)
    gr = jax.grad(loss_ref, argnums=(0, 1))(e, v)
    for name, a, c in zip(("de", "dv"), gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5,
                                   rtol=1e-5, err_msg=name)


# --------------------------------------------------------------------------
# full dispatch: every registered model, fused vs oracle vs grouped
# --------------------------------------------------------------------------


def _module_case(model, rb, n, f, di, dd, hidden, nh, seed=0):
    r = np.random.default_rng(seed)
    mod = get_relation_module(model)
    sc = ShapeCtx(hidden, nh, hidden // nh, di, dd)
    U_of = {s: u for s, u in zip(mod.scopes, (3, 2, 5, 4))}
    stacks = {
        s.name: jnp.asarray(
            r.standard_normal((U_of[s.scope],) + tuple(s.shape(sc))) * 0.1,
            jnp.float32,
        )
        for s in mod.specs
    }
    slot_np = {s: r.integers(0, U_of[s], rb) for s in mod.scopes}
    slot_u = {s: jnp.asarray(v) for s, v in slot_np.items()}
    h = jnp.asarray(r.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(r.standard_normal((rb, n, dd)), jnp.float32)
    mask = np.asarray(r.random((rb, n, f)) > 0.3)
    mask[0, 1, :] = False
    return mod, stacks, slot_np, slot_u, h, q, jnp.asarray(mask)


@pytest.mark.parametrize("model", ["rgcn", "rgat", "hgt"])
def test_stacked_agg_fused_and_grouped_match_oracle(model):
    mod, stacks, slot_np, slot_u, h, q, mask = _module_case(
        model, rb=5, n=19, f=4, di=23, dd=17, hidden=32, nh=4, seed=3
    )
    ref = stacked_agg_ref(mod, stacks, slot_u, h, q, mask)
    out = stacked_agg(mod, stacks, slot_u, h, q, mask, opts=OPTS_ON)
    grp = stacked_agg_grouped(mod, stacks, slot_np, h, q, mask)
    tol = 0 if model == "rgcn" else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)
    np.testing.assert_allclose(np.asarray(grp), np.asarray(ref), atol=1e-6)

    def loss_fused(st, h_):
        return jnp.sum(stacked_agg(mod, st, slot_u, h_, q, mask, opts=OPTS_ON) ** 2)

    def loss_ref(st, h_):
        return jnp.sum(stacked_agg_ref(mod, st, slot_u, h_, q, mask) ** 2)

    gf = jax.grad(loss_fused, argnums=(0, 1))(stacks, h)
    gr = jax.grad(loss_ref, argnums=(0, 1))(stacks, h)
    for a, c in zip(jax.tree.leaves(gf), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# fused attention epilogue: the fuse_epilogue toggle selects between the
# fully fused kernel and the attn_parts factoring — both must match the
# gather-then-vmap oracle, forward AND VJP (DESIGN.md §8)
# --------------------------------------------------------------------------

OPTS_PARTS = KernelOptions(interpret=True, fuse_epilogue=False)


@pytest.mark.parametrize("model", ["rgat", "hgt"])
@pytest.mark.parametrize("rb,n,f", [
    (5, 19, 4),    # non-block-multiple everywhere
    (3, 130, 3),   # one past the n block edge
])
def test_fused_epilogue_matches_attn_parts_and_oracle(model, rb, n, f):
    """The fused epilogue (per-slot projections streamed from the weight
    stacks) and the attn_parts oracle factoring agree with the vmap oracle
    at non-block-multiple shapes — forward and gradients, including stacks
    with shared rows (U < rb forces slot collisions)."""
    mod, stacks, slot_np, slot_u, h, q, mask = _module_case(
        model, rb=rb, n=n, f=f, di=23, dd=17, hidden=32, nh=4, seed=rb * n
    )
    # force shared stack rows: at least two slots per scope hit row 0
    slot_u = {s: jnp.asarray(np.where(np.arange(rb) < 2, 0, v))
              for s, v in slot_np.items()}

    ref = stacked_agg_ref(mod, stacks, slot_u, h, q, mask)
    fused = stacked_agg(mod, stacks, slot_u, h, q, mask, opts=OPTS_ON)
    parts = stacked_agg(mod, stacks, slot_u, h, q, mask, opts=OPTS_PARTS)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(parts), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    def loss(opts):
        def f_(st, h_):
            return jnp.sum(stacked_agg(mod, st, slot_u, h_, q, mask,
                                       opts=opts) ** 2)
        return f_

    g_fused = jax.grad(loss(OPTS_ON), argnums=(0, 1))(stacks, h)
    g_parts = jax.grad(loss(OPTS_PARTS), argnums=(0, 1))(stacks, h)
    g_ref = jax.grad(
        lambda st, h_: jnp.sum(stacked_agg_ref(mod, st, slot_u, h_, q, mask) ** 2),
        argnums=(0, 1),
    )(stacks, h)
    for a, b, c in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_parts),
                       jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=2e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                                   atol=2e-5, rtol=1e-5)


def test_fused_epilogue_grad_lands_in_stack_rows():
    """Slots sharing a projection-stack row sum their gradient contributions
    into that row (the custom VJP's stack-form gradients), and rows no slot
    references get exactly zero — the contract sync_stack_grads relies on."""
    mod, stacks, slot_np, _, h, q, mask = _module_case(
        "rgat", rb=4, n=11, f=3, di=12, dd=10, hidden=16, nh=4, seed=9
    )
    # every scope: slots 0-1 share row 0, slots 2-3 share row 1; higher rows
    # stay unused (every scope's stack has ≥2 rows in _module_case)
    slot_u = {s: jnp.asarray([0, 0, 1, 1]) for s in mod.scopes}

    def loss(st):
        return jnp.sum(stacked_agg(mod, st, slot_u, h, q, mask, opts=OPTS_ON))

    g = jax.grad(loss)(stacks)
    scope_of = {sp.name: sp.scope for sp in mod.specs}
    for name, gs in g.items():
        u_used = np.unique(np.asarray(slot_u[scope_of[name]]))
        for u in range(gs.shape[0]):
            mag = float(jnp.abs(gs[u]).max())
            if u not in u_used:
                assert mag == 0.0, f"{name}[{u}] unused but got grad {mag}"


@pytest.mark.parametrize("model", ["rgat", "hgt"])
def test_session_3step_loss_parity_fused_vs_attn_parts(model):
    """Executor-level acceptance: a 3-step training run through the fused
    epilogue produces the same losses as the attn_parts oracle factoring
    (≤1e-5), end to end through the api session."""
    from repro.api import DataConfig, Heta, HetaConfig, ModelConfig
    from repro.api import PartitionConfig, RunConfig

    def run(fuse):
        cfg = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(model=model, hidden=32),
            run=RunConfig(executor="raf_spmd", steps=3, lr=1e-2, seed=0),
        ).updated(kernels=dict(interpret=True, fuse_epilogue=fuse))
        return np.asarray(Heta(cfg).run()["losses"])

    fused, parts = run(True), run(False)
    assert fused.shape == (3,) and np.isfinite(fused).all()
    np.testing.assert_allclose(fused, parts, atol=1e-5, rtol=1e-6)


def test_stacked_agg_disabled_is_oracle():
    mod, stacks, slot_np, slot_u, h, q, mask = _module_case(
        "rgcn", rb=3, n=8, f=3, di=10, dd=10, hidden=16, nh=4, seed=4
    )
    off = stacked_agg(mod, stacks, slot_u, h, q, mask,
                      opts=KernelOptions(enabled=False))
    ref = stacked_agg_ref(mod, stacks, slot_u, h, q, mask)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(ref))


def test_vmem_budget():
    """Static VMEM per grid step stays under the 16 MiB budget at the
    paper's largest shapes (IGB-HET feature width, fanout 25)."""
    assert stacked_mean_linear_vmem_bytes(25600, 25, 1024, 64) <= 16 * 2**20
    assert stacked_mean_linear_vmem_bytes(4096, 25, 789, 349) <= 16 * 2**20


# --------------------------------------------------------------------------
# executor level: the raf_spmd fused forward is bit-equal for rgcn
# --------------------------------------------------------------------------


def test_raf_spmd_fused_forward_bit_equal_rgcn():
    """`raf_spmd` forward through the fused path (interpret mode) is
    bit-equal to the vmap path for rgcn — the executor-level acceptance
    contract on top of the op-level sweeps above."""
    from repro.core import raf_spmd
    from repro.core.hgnn import HGNNConfig, batch_to_arrays
    from repro.core.meta_partition import meta_partition
    from repro.core.raf import assign_branches
    from repro.graph.sampler import NeighborSampler, SampleSpec
    from repro.graph.synthetic import ogbn_mag_like
    from jax.sharding import PartitionSpec as P

    g = ogbn_mag_like(scale=0.002)
    mp = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp.metatree, (4, 3))
    b = NeighborSampler(g, spec, 8, seed=1).sample_batch(g.train_nodes[:8])
    cfg = HGNNConfig(model="rgcn", hidden=32, num_layers=2,
                     num_classes=g.num_classes)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    params = __import__("repro.core.hgnn", fromlist=["init_hgnn_params"]).init_hgnn_params(
        jax.random.PRNGKey(0), cfg, spec, feat_dims)

    assignment = assign_branches(spec, mp).fold(1, spec)
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    stacks = raf_spmd.stack_params_from_dict(plan, params)
    tables = {t: np.asarray(f) for t, f in g.features.items()}
    for t in g.num_nodes:
        if t not in tables:
            tables[t] = np.zeros((g.num_nodes[t], cfg.learnable_dim), np.float32)
    arrays = raf_spmd.stack_batch(plan, b, tables)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    arr_specs = raf_spmd._array_specs(plan, ("data",), "model")
    rel_specs = {k: v for k, v in raf_spmd._stack_specs(plan).items() if k != "head"}
    feats = {k: v for k, v in arrays.items() if "feat" in k}
    rest = {k: v for k, v in arrays.items() if "feat" not in k}

    def run(kernels):
        def body(st, fe, re_):
            return raf_spmd.raf_spmd_forward(plan, st, {**fe, **re_}, "model",
                                             True, kernels)
        return raf_spmd.shard_map_nocheck(
            body, mesh=mesh,
            in_specs=(rel_specs, {k: arr_specs[k] for k in feats},
                      {k: arr_specs[k] for k in rest}),
            out_specs=P(("data",), None),
        )({k: v for k, v in stacks.items() if k != "head"}, feats, rest)

    vmap_root = run(KernelOptions(enabled=False))
    fused_root = run(KernelOptions(interpret=True))
    np.testing.assert_array_equal(np.asarray(fused_root), np.asarray(vmap_root))
