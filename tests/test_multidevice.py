"""Multi-device integration tests (subprocess: jax locks the device count on
first import, so these spawn fresh interpreters with 8 host devices)."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_spmd_raf_training_multidevice():
    """4-partition RAF on a (2 data × 4 model) mesh: bit-equivalence with the
    single-device reference forward, and a training loop whose loss falls."""
    out = _run(
        r"""
import numpy as np, jax, jax.numpy as jnp, json
from repro.graph.synthetic import ogbn_mag_like
from repro.core.meta_partition import meta_partition
from repro.graph.sampler import SampleSpec, NeighborSampler
from repro.core.hgnn import HGNNConfig, init_hgnn_params, init_embed_tables, hgnn_forward, batch_to_arrays
from repro.core.raf import assign_branches
from repro.core import raf_spmd
from repro.optim.adam import AdamConfig, adam_init

g = ogbn_mag_like(scale=0.002)
Pn = 4
mp = meta_partition(g, Pn, num_layers=2)
spec = SampleSpec.from_metatree(mp.metatree, [4, 3])
sampler = NeighborSampler(g, spec, 16, seed=0)
batch = sampler.sample_batch(g.train_nodes[:16])
feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
cfg = HGNNConfig(model="rgcn", hidden=32, num_layers=2, num_classes=g.num_classes)
params = init_hgnn_params(jax.random.PRNGKey(0), cfg, spec, feat_dims)
params["embed"] = init_embed_tables(jax.random.PRNGKey(1), cfg, g.num_nodes, feat_dims)
ref = hgnn_forward(cfg, params, {t: jnp.asarray(f) for t, f in g.features.items()},
                   batch_to_arrays(batch), spec)

assignment = assign_branches(spec, mp)
plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
stacks = raf_spmd.stack_params_from_dict(plan, params)
tables = {t: np.asarray(f) for t, f in g.features.items()}
tables.update({t: np.asarray(v) for t, v in params["embed"].items()})
arrays = raf_spmd.stack_batch(plan, batch, tables)

mesh = jax.make_mesh((2, 4), ("data", "model"))
arrays_s = raf_spmd.shard_arrays(plan, mesh, arrays)
stacks_s = raf_spmd.shard_stacks(plan, mesh, stacks)
step = raf_spmd.make_train_step(plan, mesh, AdamConfig(lr=5e-3), data_axes=("data",))
opt = adam_init(stacks_s)
losses = []
for i in range(6):
    stacks_s, opt, loss = step(stacks_s, opt, arrays_s)
    losses.append(float(loss))
print(json.dumps({"losses": losses}))
assert losses[-1] < losses[0], losses
assert all(np.isfinite(losses))
"""
    )
    losses = json.loads(out.strip().splitlines()[-1])["losses"]
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_meta_vs_naive_collectives():
    """The paper's communication claim at the HLO level: with meta-local
    placement the only model-axis collective payload is the root partial
    [B, hidden]; naive placement's inner-level psum is larger by ~fanout×R."""
    out = _run(
        r"""
import numpy as np, jax, jax.numpy as jnp, json
from repro.graph.synthetic import ogbn_mag_like
from repro.core.meta_partition import meta_partition
from repro.graph.sampler import SampleSpec, NeighborSampler
from repro.core.hgnn import HGNNConfig, init_hgnn_params, init_embed_tables
from repro.core.raf import assign_branches, random_branch_assignment
from repro.core import raf_spmd
from repro.optim.adam import AdamConfig, adam_init
from repro.launch.dryrun import collective_bytes

g = ogbn_mag_like(scale=0.002)
mp = meta_partition(g, 4, num_layers=2)
# paper-scale fanouts/batch so the inner-level exchange dominates the fixed
# collectives (loss psum, feature all-gathers)
spec = SampleSpec.from_metatree(mp.metatree, [12, 10])
sampler = NeighborSampler(g, spec, 64, seed=0)
batch = sampler.sample_batch(g.train_nodes[:64])
feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
cfg = HGNNConfig(model="rgcn", hidden=64, num_layers=2, num_classes=g.num_classes)
params = init_hgnn_params(jax.random.PRNGKey(0), cfg, spec, feat_dims)
params["embed"] = init_embed_tables(jax.random.PRNGKey(1), cfg, g.num_nodes, feat_dims)
tables = {t: np.asarray(f) for t, f in g.features.items()}
tables.update({t: np.asarray(v) for t, v in params["embed"].items()})
mesh = jax.make_mesh((2, 4), ("data", "model"))

results = {}
for mode, assignment, local in (
    ("meta", assign_branches(spec, mp), True),
    ("naive", random_branch_assignment(spec, 4, seed=5), False),
):
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    stacks = raf_spmd.shard_stacks(plan, mesh, raf_spmd.stack_params_from_dict(plan, params))
    arrays = raf_spmd.shard_arrays(plan, mesh, raf_spmd.stack_batch(plan, batch, tables))
    step = raf_spmd.make_train_step(plan, mesh, AdamConfig(), data_axes=("data",), local_combine=local)
    lowered = step.lower(stacks, adam_init(stacks), arrays)
    hlo = lowered.compile().as_text()
    results[mode] = collective_bytes(hlo).get("total", 0)
print(json.dumps(results))
assert results["naive"] > 2 * results["meta"], results
"""
    )
    res = json.loads(out.strip().splitlines()[-1])
    assert res["naive"] > 2 * res["meta"]


@pytest.mark.slow
def test_hgnn_driver_end_to_end():
    """launch/train.py driver: full Heta pipeline (partition → presample →
    cache → SPMD RAF train) for a few steps on 8 devices."""
    out = _run(
        r"""
from repro.launch.train import train_hgnn
metrics = train_hgnn(dataset="ogbn-mag", scale=0.002, model="rgcn",
                     num_partitions=4, mesh_shape=(2, 4), batch_size=16,
                     fanouts=(4, 3), steps=6, cache_mb=2, seed=0)
import json
import numpy as np
print(json.dumps({"first": metrics["losses"][0], "last": metrics["losses"][-1],
                  "hit_rates": metrics["hit_rates"]}))
# fresh batches each step: assert finiteness + pipeline health (the fixed-
# batch loss-decrease property is covered by the SPMD training test above)
assert all(np.isfinite(metrics["losses"]))
assert metrics["meta_local"]
"""
    )
    assert "first" in out
