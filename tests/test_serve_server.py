"""Micro-batcher flush/backpressure/shutdown/failure discipline and the
EmbeddingServer hot path (repro.serve.server), plus the ServeConfig section
and the "serve" executor registration."""

import threading
import time

import numpy as np
import pytest

from repro.serve.server import EmbeddingServer, MicroBatcher, _build_serve_cache
from repro.serve.full_graph import EmbeddingStore


def _echo(items):
    return list(items)


# --------------------------------------------------------------------------
# MicroBatcher
# --------------------------------------------------------------------------


def test_flush_on_size():
    """A full batch flushes immediately, without waiting out the deadline."""
    seen = []

    def process(items):
        seen.append(list(items))
        return items

    with MicroBatcher(process, max_batch=4, max_wait_ms=10_000) as mb:
        futs = [mb.submit(i) for i in range(4)]
        t0 = time.monotonic()
        assert [f.result(timeout=5) for f in futs] == [0, 1, 2, 3]
        assert time.monotonic() - t0 < 5  # nowhere near the 10 s budget
    assert seen[0] == [0, 1, 2, 3]


def test_flush_on_deadline():
    """A lone request flushes once the oldest item ages past max_wait_ms."""
    with MicroBatcher(_echo, max_batch=1000, max_wait_ms=30) as mb:
        t0 = time.monotonic()
        assert mb.submit(42).result(timeout=5) == 42
        dt = time.monotonic() - t0
        assert dt >= 0.02  # waited for the deadline, not a size flush


def test_batches_respect_max_batch():
    sizes = []

    def process(items):
        sizes.append(len(items))
        return items

    with MicroBatcher(process, max_batch=3, max_wait_ms=50) as mb:
        futs = [mb.submit(i) for i in range(8)]
        for f in futs:
            f.result(timeout=5)
    assert max(sizes) <= 3
    assert sum(sizes) == 8


def test_backpressure_blocks_submitters():
    """submit blocks while max_queue items are pending, resumes post-flush."""
    release = threading.Event()

    def process(items):
        release.wait(5)
        return items

    mb = MicroBatcher(process, max_batch=2, max_wait_ms=1, max_queue=2)
    try:
        f1, f2 = mb.submit(1), mb.submit(2)  # flushes; process blocks
        time.sleep(0.05)
        # queue free again (flush popped them) -> fill it while blocked
        f3, f4 = mb.submit(3), mb.submit(4)
        done = threading.Event()
        slot = {}

        def blocked_submit():
            slot["fut"] = mb.submit(5)  # queue full: must block
            done.set()

        t = threading.Thread(target=blocked_submit)
        t.start()
        assert not done.wait(0.2)  # still blocked on backpressure
        release.set()  # unblock process -> batches drain -> queue frees
        assert done.wait(5)
        assert slot["fut"].result(timeout=5) == 5
        for f in (f1, f2, f3, f4):
            assert f.result(timeout=5) in (1, 2, 3, 4)
        t.join()
    finally:
        release.set()
        mb.close()


def test_shutdown_drains_in_flight():
    """close() answers every pending request before the flusher exits."""
    slow = MicroBatcher(lambda items: (time.sleep(0.01), items)[1],
                        max_batch=2, max_wait_ms=10_000)
    futs = [slow.submit(i) for i in range(2)]  # flushing now
    late = slow.submit(99)  # pending behind the in-flight flush
    slow.close()
    assert [f.result(timeout=1) for f in futs] == [0, 1]
    assert late.result(timeout=1) == 99
    with pytest.raises(RuntimeError, match="closed"):
        slow.submit(1)
    slow.close()  # idempotent


def test_exception_propagates_to_flush_callers_only():
    """A failing flush rejects exactly its own callers; the batcher lives."""
    def process(items):
        if any(i < 0 for i in items):
            raise ZeroDivisionError("bad item")
        return items

    with MicroBatcher(process, max_batch=1, max_wait_ms=1) as mb:
        bad = mb.submit(-1)
        with pytest.raises(ZeroDivisionError, match="bad item"):
            bad.result(timeout=5)
        # still serving after the failure
        assert mb.submit(7).result(timeout=5) == 7


def test_result_count_mismatch_is_an_error():
    with MicroBatcher(lambda items: items[:-1] if len(items) > 1 else items,
                      max_batch=2, max_wait_ms=1) as mb:
        f1, f2 = mb.submit(1), mb.submit(2)
        with pytest.raises(RuntimeError, match="results"):
            f1.result(timeout=5)
        with pytest.raises(RuntimeError):
            f2.result(timeout=5)


def test_validation():
    with pytest.raises(ValueError, match="max_batch"):
        MicroBatcher(_echo, max_batch=0)


# --------------------------------------------------------------------------
# EmbeddingServer over a hand-built store
# --------------------------------------------------------------------------


def _toy_store(n=50, hidden=8, classes=5, types=("paper", "author"), seed=0):
    rng = np.random.default_rng(seed)
    emb = {t: rng.normal(size=(n, hidden)).astype(np.float32) for t in types}
    return EmbeddingStore(
        target_type=types[0], num_classes=classes, hidden=hidden,
        embeddings=emb, layer_of={t: 2 for t in types},
        head={"w": rng.normal(size=(hidden, classes)).astype(np.float32),
              "b": np.zeros(classes, np.float32)},
    )


def test_server_scores_and_embeddings():
    store = _toy_store()
    with EmbeddingServer(store, max_batch=8, max_wait_ms=1) as srv:
        nids = np.array([3, 1, 4])
        res = srv.query(nids)
        np.testing.assert_array_equal(
            res.embeddings, store.embedding("paper", nids))
        np.testing.assert_allclose(
            res.scores, store.scores(nids), atol=1e-6)
        assert res.latency_ms >= 0
        # non-target types return embeddings only
        res_a = srv.query([0, 2], ntype="author")
        assert res_a.scores is None
        np.testing.assert_array_equal(
            res_a.embeddings, store.embedding("author", [0, 2]))
        with pytest.raises(KeyError, match="no materialized"):
            srv.query([0], ntype="venue")


def test_server_coalesces_concurrent_lookups():
    """Concurrent queries of one type land in one flush: one fetch per type,
    answers split back per request."""
    store = _toy_store()
    with EmbeddingServer(store, max_batch=16, max_wait_ms=20) as srv:
        results = {}

        def client(k):
            results[k] = srv.query([k, k + 1])

        threads = [threading.Thread(target=client, args=(k,)) for k in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for k, res in results.items():
            np.testing.assert_array_equal(
                res.embeddings, store.embedding("paper", [k, k + 1]))
        stats = srv.stats()
        assert stats.count == 6
        assert stats.flushes < 6  # coalesced
        assert stats.p99_ms >= stats.p50_ms >= 0.0
        assert stats.qps > 0


def test_server_hit_rates_reported():
    store = _toy_store(n=64)
    # budget covers every row of both 64x8 f32 tables -> all hits
    with EmbeddingServer(store, max_batch=4, max_wait_ms=1, cache_mb=1) as srv:
        srv.query([1, 2, 3])
        rates = srv.stats().hit_rates
        assert rates["paper"] == 1.0
    # zero budget -> no type cache -> fetch falls through to host (no entry)
    with EmbeddingServer(store, max_batch=4, max_wait_ms=1, cache_mb=0) as srv:
        res = srv.query([1, 2, 3])
        np.testing.assert_array_equal(
            res.embeddings, store.embedding("paper", [1, 2, 3]))
        assert srv.stats().hit_rates == {}


def test_build_serve_cache_budgets():
    store = _toy_store(n=100)
    cache = _build_serve_cache(store, cache_mb=0)
    assert cache.caches == {}
    cache = _build_serve_cache(store, cache_mb=1)
    for t in store.embeddings:
        assert cache.caches[t].data.shape[0] == 100  # fully resident
    assert cache.consistency_check()


def test_server_online_readmit_beats_one_shot():
    """Online re-admission from the served-id trace: a Zipf request mix
    whose hot set is *not* the low-id rows the uniform one-shot policy
    caches must end up with a strictly better hit rate after readmits."""
    rng = np.random.default_rng(0)
    n, hidden = 16384, 64
    emb = {t: rng.normal(size=(n, hidden)).astype(np.float32)
           for t in ("paper", "author")}
    store = EmbeddingStore(
        target_type="paper", num_classes=5, hidden=hidden,
        embeddings=emb, layer_of={t: 2 for t in emb},
        head={"w": rng.normal(size=(hidden, 5)).astype(np.float32),
              "b": np.zeros(5, np.float32)},
    )
    perm = rng.permutation(n)

    def draw(k=64):
        return perm[np.minimum(rng.zipf(1.5, size=k) - 1, n - 1)]

    with EmbeddingServer(store, cache_mb=1, max_wait_ms=0.2,
                         readmit_every=10) as srv:
        for _ in range(40):
            srv.query(draw(), "paper")
        assert srv.readmits >= 1
        srv.cache.reset_stats()
        for _ in range(40):
            srv.query(draw(), "paper")
        online = srv.stats().hit_rates["paper"]
        assert srv.cache.consistency_check()
    with EmbeddingServer(store, cache_mb=1, max_wait_ms=0.2) as srv:
        for _ in range(40):
            srv.query(draw(), "paper")
        one_shot = srv.stats().hit_rates["paper"]
    assert online > one_shot
    assert online > 0.8


# --------------------------------------------------------------------------
# ServeConfig + the "serve" executor registration
# --------------------------------------------------------------------------


def test_serve_config_roundtrip():
    from repro.api import HetaConfig, ServeConfig
    from repro.api.config import config_from_args, add_config_args
    import argparse

    cfg = HetaConfig(serve=ServeConfig(max_batch=8, max_wait_ms=1.5, shm=True))
    assert HetaConfig.from_dict(cfg.to_dict()) == cfg
    flat = cfg.to_flat_kwargs()
    assert flat["serve_max_batch"] == 8
    assert flat["serve_shm"] is True
    assert HetaConfig.from_flat_kwargs(**flat) == cfg

    ap = argparse.ArgumentParser()
    add_config_args(ap)
    args = ap.parse_args(["--serve-max-batch", "3", "--serve-max-wait-ms",
                          "0.5", "--serve-shm"])
    got = config_from_args(args)
    assert got.serve.max_batch == 3
    assert got.serve.max_wait_ms == 0.5
    assert got.serve.shm is True

    with pytest.raises(ValueError, match="max_queue"):
        ServeConfig(max_batch=100, max_queue=10)
    with pytest.raises(ValueError, match="node_block"):
        ServeConfig(node_block=0)


def test_serve_executor_registered_and_guarded():
    from repro.api import HetaStageError, executors
    from repro.api.session import Heta

    assert "serve" in executors.available()
    sess = Heta()
    sess.build_graph()
    sess.partition()
    sess.profile_and_cache()
    with pytest.raises(HetaStageError, match="infer_all"):
        sess.compile(executor="serve")
    with pytest.raises(HetaStageError, match="infer_all"):
        sess.serve()


# --------------------------------------------------------------------------
# degradation policy (DESIGN.md §12): retries, circuit breaker, cache bypass
# --------------------------------------------------------------------------


def test_serve_transient_flush_failure_is_retried():
    """One injected primary failure: the retry path answers the request
    from the primary (no degradation, no trip), and the retry is counted."""
    from repro.data.faults import FaultPlan, FaultSpec

    store = _toy_store()
    plan = FaultPlan((FaultSpec("fail_flush", step=0, count=1),))
    with EmbeddingServer(store, max_batch=8, max_wait_ms=1, faults=plan,
                         flush_retries=2, retry_backoff_ms=0.1) as srv:
        res = srv.query([3, 1, 4])
        np.testing.assert_array_equal(
            res.embeddings, store.embedding("paper", [3, 1, 4]))
        stats = srv.stats()
        assert stats.retries == 1
        assert stats.degraded == 0
        assert stats.breaker_trips == 0
        assert stats.breaker_state == "closed"


def test_serve_breaker_trips_and_degrades_with_zero_rejects():
    """Persistent primary failure: after breaker_threshold consecutive
    flush failures (each retried flush_retries times) the breaker opens
    and every request — including the failing ones — is answered from the
    degraded direct-store path.  Zero rejected callers, answers exact."""
    from repro.data.faults import FaultPlan, FaultSpec

    store = _toy_store()
    # threshold=2 failures x (1 retry + 1) attempts = 4 faulted attempts
    plan = FaultPlan((FaultSpec("fail_flush", step=0, count=4),))
    with EmbeddingServer(store, max_batch=8, max_wait_ms=1, faults=plan,
                         flush_retries=1, retry_backoff_ms=0.1,
                         breaker_threshold=2,
                         breaker_cooldown_ms=60_000) as srv:
        for k in range(4):  # 2 tripping flushes + 2 served while open
            res = srv.query([k, k + 1])
            np.testing.assert_array_equal(
                res.embeddings, store.embedding("paper", [k, k + 1]))
            np.testing.assert_allclose(
                res.scores, store.scores(np.array([k, k + 1])), atol=1e-5)
        stats = srv.stats()
        assert stats.count == 4  # every caller answered
        assert stats.breaker_state == "open"
        assert stats.breaker_trips == 1
        assert stats.degraded == 4
        assert stats.retries == 2


def test_serve_breaker_recovers_after_cooldown():
    """Half-open probe: once the cooldown elapses a single probe flush
    runs the primary again; success closes the breaker."""
    from repro.data.faults import FaultPlan, FaultSpec

    store = _toy_store()
    plan = FaultPlan((FaultSpec("fail_flush", step=0, count=1),))
    with EmbeddingServer(store, max_batch=8, max_wait_ms=1, faults=plan,
                         flush_retries=0, breaker_threshold=1,
                         breaker_cooldown_ms=50) as srv:
        srv.query([1, 2])  # fails, trips, degraded
        assert srv.stats().breaker_state == "open"
        time.sleep(0.12)  # past the cooldown
        res = srv.query([3, 4])  # half-open probe succeeds
        np.testing.assert_array_equal(
            res.embeddings, store.embedding("paper", [3, 4]))
        stats = srv.stats()
        assert stats.breaker_state == "closed"
        assert stats.breaker_recoveries == 1
        assert stats.degraded == 1  # only the tripping flush degraded


def test_serve_flush_delay_and_default_deadline():
    """delay_flush slows the primary; deadline_ms sets query's default
    result timeout so a healthy-but-slow flush still answers in time."""
    from repro.data.faults import FaultPlan, FaultSpec

    store = _toy_store()
    plan = FaultPlan((FaultSpec("delay_flush", step=0, delay_s=0.05),))
    with EmbeddingServer(store, max_batch=8, max_wait_ms=1, faults=plan,
                         deadline_ms=2000.0) as srv:
        res = srv.query([5, 6])  # no explicit timeout: deadline drives it
        assert res.latency_ms >= 50.0
        np.testing.assert_array_equal(
            res.embeddings, store.embedding("paper", [5, 6]))
