"""Optimizer: AdamW against a hand-rolled reference; sparse row updates."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim.adam import (
    AdamConfig,
    adam_init,
    adam_update,
    global_norm,
    sparse_adam_rows,
)


def _ref_adam(p, g, m, v, t, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m / (1 - cfg.b1**t)
    vhat = v / (1 - cfg.b2**t)
    return p - cfg.lr * (mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adam_matches_reference():
    cfg = AdamConfig(lr=0.1, weight_decay=0.01)
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal((4, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adam_init(params)
    m = v = np.zeros_like(p0)
    p = p0.copy()
    for t in range(1, 5):
        g = rng.standard_normal(p0.shape).astype(np.float32)
        params, state = adam_update(cfg, params, {"w": jnp.asarray(g)}, state)
        p, m, v = _ref_adam(p, g, m, v, t, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), p, atol=1e-5)


def test_grad_clip():
    cfg = AdamConfig(lr=1.0, grad_clip=1.0)
    params = {"w": jnp.zeros((10,))}
    state = adam_init(params)
    big = {"w": jnp.full((10,), 100.0)}
    new, _ = adam_update(cfg, params, big, state)
    # post-clip step size bounded by lr (bias-corrected adam step ≈ ±lr)
    assert float(jnp.abs(new["w"]).max()) <= 1.01 * cfg.lr


def test_sparse_rows_match_dense():
    cfg = AdamConfig(lr=0.05)
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)
    grads = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)
    m = jnp.zeros((6, 4))
    v = jnp.zeros((6, 4))
    new, nm, nv = sparse_adam_rows(cfg, rows, grads, m, v, jnp.asarray(0))

    params = {"w": rows}
    state = adam_init(params)
    dense, _ = adam_update(cfg, params, {"w": grads}, state)
    np.testing.assert_allclose(np.asarray(new), np.asarray(dense["w"]), atol=1e-6)


@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_global_norm_property(a, b, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((a, b)).astype(np.float32)
    tree = {"a": jnp.asarray(x), "b": {"c": jnp.asarray(x * 2)}}
    want = np.sqrt((x**2).sum() + (4 * x**2).sum())
    np.testing.assert_allclose(float(global_norm(tree)), want, rtol=1e-5)
