"""Fault injection + worker supervision (DESIGN.md §12): FaultPlan
semantics, dead-worker respawn with deterministic stripe replay, arena
slot invalidation, writer stall detection, and the end-to-end chaos drill
— a pooled frozen-snapshot fit that loses a sampler worker mid-run must
finish with bit-identical losses."""

import dataclasses
import os
import time

import numpy as np
import pytest

from repro.core.metatree import build_metatree
from repro.data.faults import (
    KILL_EXIT_CODE,
    FaultPlan,
    FaultSpec,
    InjectedFault,
)
from repro.data.worker_pool import (
    EpochSchedule,
    SampleStageTask,
    WorkerDiedError,
    WorkerPool,
)
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.shm import create_arena, live_segments, share_graph
from repro.graph.synthetic import ogbn_mag_like

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="fault drills rely on /dev/shm"
)


# --------------------------------------------------------------------------
# FaultPlan — deterministic coordinates, no wall-clock
# --------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("segfault", step=0)
    with pytest.raises(ValueError, match="step"):
        FaultSpec("kill_worker", step=-1)
    with pytest.raises(ValueError, match="count"):
        FaultSpec("fail_flush", step=0, count=0)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec("delay_flush", step=0, delay_s=-0.1)


def test_fault_plan_worker_queries():
    plan = FaultPlan((
        FaultSpec("kill_worker", step=5, worker=1),
        FaultSpec("raise_item", step=2),
        FaultSpec("poison_slot", step=4, first_attempt_only=False),
    ))
    assert plan
    # worker filter: only worker 1, only item 5
    assert plan.kill_at(1, 0, 5)
    assert not plan.kill_at(0, 0, 5)
    assert not plan.kill_at(1, 0, 3)
    # first_attempt_only (default): the respawned incarnation sails through
    assert not plan.kill_at(1, 1, 5)
    assert plan.raise_at(0, 0, 2) and not plan.raise_at(0, 1, 2)
    # first_attempt_only=False keeps firing on replays
    assert plan.poison_at(0, 3, 4)
    assert not FaultPlan()


def test_fault_plan_flush_queries():
    plan = FaultPlan((
        FaultSpec("fail_flush", step=3, count=2),
        FaultSpec("delay_flush", step=0, delay_s=0.25),
    ))
    assert plan.flush_fault(2) is None
    assert plan.flush_fault(3) is not None and plan.flush_fault(4) is not None
    assert plan.flush_fault(5) is None
    assert plan.flush_delay(0) == 0.25
    assert plan.flush_delay(1) == 0.0


def test_fault_plan_json_round_trip():
    plan = FaultPlan((
        FaultSpec("kill_worker", step=5, worker=1),
        FaultSpec("fail_flush", step=0, count=3, first_attempt_only=False),
        FaultSpec("delay_flush", step=2, delay_s=0.5),
    ))
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert FaultPlan.from_json(FaultPlan().to_json()) == FaultPlan()


# --------------------------------------------------------------------------
# worker supervision — respawn budget, stripe replay, loud failure modes
# --------------------------------------------------------------------------

# task classes live at module level so spawn can unpickle them in workers


@dataclasses.dataclass
class ChaosTask:
    """Minimal pool task with the SampleStageTask fault hooks."""

    faults: FaultPlan

    def setup(self):
        pass

    def bind_worker(self, wid, attempt):
        self._wid, self._attempt = wid, attempt

    def __call__(self, i):
        if self.faults.kill_at(self._wid, self._attempt, i):
            os._exit(KILL_EXIT_CODE)  # silent death: no queue message
        if self.faults.raise_at(self._wid, self._attempt, i):
            raise InjectedFault(f"scheduled raise at {i}")
        return i * i

    def teardown(self):
        pass


def test_respawn_replays_stripe_and_records_event():
    """Killing worker 1 mid-stripe: the supervisor respawns it from the
    consumer's position and the full ordered stream still arrives."""
    task = ChaosTask(FaultPlan((FaultSpec("kill_worker", step=5, worker=1),)))
    with WorkerPool(task, num_workers=2, depth=2, num_items=12,
                    max_restarts=2, restart_backoff_s=0.01) as pool:
        assert list(pool) == [i * i for i in range(12)]
        assert len(pool.restarts) == 1
        ev = pool.restarts[0]
        assert ev["wid"] == 1
        assert ev["exitcode"] == KILL_EXIT_CODE
        assert ev["attempt"] == 1
        # detection may fire before the kill item: os._exit can lose
        # already-queued items still in the feeder thread, and replay
        # covers them -- so the position is any of worker 1's stripe
        # items up to the kill point
        assert ev["item"] in (1, 3, 5)
        assert ev["downtime_s"] >= 0.0


def test_restart_budget_exhausted_raises_with_exit_code():
    task = ChaosTask(FaultPlan((FaultSpec("kill_worker", step=3),)))
    pool = WorkerPool(task, num_workers=2, depth=1, num_items=8,
                      max_restarts=0)
    got = []
    with pytest.raises(WorkerDiedError, match=r"code 73.*restarts used: 0/0"):
        for x in pool:
            got.append(x)
    assert got == [i * i for i in range(len(got))]  # prefix stayed ordered
    assert all(not p.is_alive() for p in pool._procs)
    with pytest.raises(RuntimeError, match="closed"):
        next(pool)


def test_persistent_kill_exhausts_respawn_budget():
    """first_attempt_only=False kills every incarnation at the same item:
    the budget burns down and the final error names it."""
    task = ChaosTask(FaultPlan((
        FaultSpec("kill_worker", step=2, first_attempt_only=False),)))
    pool = WorkerPool(task, num_workers=2, depth=1, num_items=8,
                      max_restarts=1, restart_backoff_s=0.01)
    with pytest.raises(WorkerDiedError, match=r"restarts used: 1/1"):
        list(pool)
    assert len(pool.restarts) == 1  # one respawn happened before giving up


def test_injected_raise_propagates_without_respawn():
    """raise_item is a *loud* failure (the worker ships the traceback);
    supervision only covers silent deaths, so no restart is consumed."""
    task = ChaosTask(FaultPlan((FaultSpec("raise_item", step=2),)))
    pool = WorkerPool(task, num_workers=2, depth=1, num_items=8,
                      max_restarts=2)
    with pytest.raises(InjectedFault, match="scheduled raise at 2"):
        list(pool)
    assert pool.restarts == []
    assert all(not p.is_alive() for p in pool._procs)


def test_on_worker_death_hook_runs_before_respawn():
    deaths = []
    task = ChaosTask(FaultPlan((FaultSpec("kill_worker", step=0, worker=0),)))
    with WorkerPool(task, num_workers=2, depth=1, num_items=6,
                    max_restarts=1, restart_backoff_s=0.01,
                    on_worker_death=deaths.append) as pool:
        assert list(pool) == [i * i for i in range(6)]
    assert deaths == [0]


def test_supervision_validation():
    with pytest.raises(ValueError, match="max_restarts"):
        WorkerPool(ChaosTask(FaultPlan()), num_workers=1, max_restarts=-1)


# --------------------------------------------------------------------------
# SampleStageTask under faults — replay determinism over the shm store
# --------------------------------------------------------------------------


def _mag():
    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    return g, SampleSpec.from_metatree(tree, [3, 2])


def _assert_batches_equal(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(a.labels, b.labels)
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(la.nids, lb.nids)
        np.testing.assert_array_equal(la.mask, lb.mask)


def test_sampler_kill_replay_bit_identical_to_serial():
    """A killed sampler worker's stripe is replayed by its replacement:
    every delivered batch still matches the serial sampler bit-for-bit."""
    g, spec = _mag()
    serial = NeighborSampler(g, spec, 8, seed=5)
    E = serial.steps_per_epoch()
    store = share_graph(g, include_features=False)
    try:
        task = SampleStageTask(
            handle=store.handle, spec=spec, batch_size=8, sampler_seed=5,
            schedule=EpochSchedule(77, E),
            faults=FaultPlan((FaultSpec("kill_worker", step=3),)),
        )
        n = 6
        with WorkerPool(task, num_workers=2, depth=2, num_items=n,
                        max_restarts=1, restart_backoff_s=0.01) as pool:
            for i, (batch, host, host_s) in enumerate(pool):
                seed, idx = EpochSchedule(77, E).seed_and_index(i)
                _assert_batches_equal(batch, serial.batch_at(idx, epoch_seed=seed))
                assert host is None and host_s >= 0.0
            assert len(pool.restarts) == 1
            assert pool.restarts[0]["exitcode"] == KILL_EXIT_CODE
    finally:
        store.unlink()
    assert not live_segments(store.handle.segment)


def _probe_fields():
    return {"x": np.zeros((4, 3), np.float32), "y": np.zeros(4, np.int64)}


def test_poisoned_slot_resolves_loudly_and_heals_on_rewrite():
    """poison_slot models a torn write: resolve raises instead of returning
    garbage, and the next begin_write heals the stamp."""
    with create_arena(_probe_fields(), num_workers=1, depth=1) as a:
        a.begin_write(0, 0)
        a.slot_views(0, writable=True)["x"][:] = 7.0
        a.end_write(0, 0)
        a.poison_slot(0)
        with pytest.raises(RuntimeError, match="invalidated"):
            a.resolve(0, 0)
        # release still works (backpressure bookkeeping is separate) and
        # the replacement generation heals the stamp
        a.release(0, 0)
        assert a.wait_writable(0, 1, timeout=1.0)
        a.begin_write(0, 1)
        a.slot_views(0, writable=True)["x"][:] = 8.0
        a.end_write(0, 1)
        assert float(a.resolve(0, 1)["x"][0, 0]) == 8.0


def test_invalidate_worker_slots_scopes_to_one_worker():
    """The supervisor's death hook poisons only the dead worker's sub-ring;
    the surviving worker's in-flight slots stay resolvable."""
    with create_arena(_probe_fields(), num_workers=2, depth=2) as a:
        for i in range(4):  # one generation of every slot
            slot, use = a.handle.slot_for(i)
            a.begin_write(slot, use)
            a.slot_views(slot, writable=True)["x"][:] = float(i)
            a.end_write(slot, use)
        a.invalidate_worker_slots(0)
        for i in (0, 2):  # worker 0's items
            slot, use = a.handle.slot_for(i)
            with pytest.raises(RuntimeError, match="invalidated"):
                a.resolve(slot, use)
        for i in (1, 3):  # worker 1 untouched
            slot, use = a.handle.slot_for(i)
            assert float(a.resolve(slot, use)["x"][0, 0]) == float(i)


def test_arena_writer_stall_raises_named_error():
    """A wedged consumer (never releases) must fail the writer loudly
    after write_timeout_s, not hang it forever."""
    from repro.data.staging import arena_fields

    g, spec = _mag()
    serial = NeighborSampler(g, spec, 8, seed=0)
    store = share_graph(g, include_features=False)
    arena = create_arena(arena_fields(serial.batch_at(0, epoch_seed=0)),
                         num_workers=1, depth=1)
    task = SampleStageTask(
        handle=store.handle, spec=spec, batch_size=8, sampler_seed=0,
        schedule=EpochSchedule(0, serial.steps_per_epoch()),
        arena=arena.handle, write_timeout_s=0.1,
    )
    try:
        task.bind_worker(0, 0)
        task.setup()
        ref = task(0)
        assert ref.slot == 0 and ref.use == 0
        from repro.graph.shm import ArenaStalledError

        t0 = time.perf_counter()
        with pytest.raises(ArenaStalledError, match="not writable"):
            task(1)  # same slot, generation 1 -- never released
        assert time.perf_counter() - t0 < 5.0
    finally:
        task.teardown()
        arena.unlink()
        store.unlink()


# --------------------------------------------------------------------------
# end-to-end chaos drill: pooled fit loses a worker, losses bit-identical
# --------------------------------------------------------------------------


def _chaos_config():
    from repro.api import (CacheConfig, DataConfig, FaultConfig, HetaConfig,
                           ModelConfig, PartitionConfig, PipelineConfig,
                           RunConfig)

    return HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                        batch_size=8),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=32),
        cache=CacheConfig(cache_mb=2, presample_epochs=1),
        run=RunConfig(executor="raf_spmd", steps=10, lr=1e-2, seed=0),
        pipeline=PipelineConfig(enabled=True, num_workers=2, depth=2,
                                snapshot="fresh"),
        faults=FaultConfig(max_worker_restarts=2, worker_backoff_s=0.01),
    )


def test_pooled_fit_survives_worker_kill_bit_identical():
    """ISSUE 9 acceptance (a): a pooled frozen-snapshot fit that loses a
    worker mid-run respawns it, replays the stripe, and produces
    bit-identical losses to the undisturbed run."""
    from repro.api import Heta

    ref = Heta(_chaos_config()).run()

    drill = Heta(_chaos_config())
    drill.fault_plan = FaultPlan((FaultSpec("kill_worker", step=5),))
    try:
        got = drill.run()
        pool = drill._pool_cache[2]
        assert len(pool.restarts) == 1
        ev = pool.restarts[0]
        assert ev["exitcode"] == KILL_EXIT_CODE and ev["attempt"] == 1
    finally:
        drill.close_pipeline()
    assert got["losses"] == ref["losses"]  # bit-identical


def test_pooled_fit_budget_exhaustion_is_loud():
    """With respawn disabled the same drill dies with the named error —
    never a hang, never silent truncation of the epoch."""
    from repro.api import Heta

    drill = Heta(_chaos_config().updated(faults=dict(max_worker_restarts=0)))
    drill.fault_plan = FaultPlan((FaultSpec("kill_worker", step=5),))
    try:
        with pytest.raises(WorkerDiedError, match="code 73"):
            drill.run()
    finally:
        drill.close_pipeline()
