"""Shared-memory graph store (repro.graph.shm): attach round-trip
bit-equality, zero-copy views, lifecycle (close/unlink), and the no-leaked-
segments guarantee on error paths."""

import os
import pickle

import numpy as np
import pytest

from repro.core.metatree import build_metatree
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.shm import attach, live_segments, share_graph
from repro.graph.synthetic import ogbn_mag_like

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="no /dev/shm on this platform"
)


def _graph():
    return ogbn_mag_like(scale=0.002)


def _spec(g, fanouts=(3, 2)):
    tree = build_metatree(g.metagraph(), g.target_type, len(fanouts))
    return SampleSpec.from_metatree(tree, fanouts)


def test_attach_round_trip_bit_equal():
    g = _graph()
    tables = {"paper": g.features["paper"].astype(np.float32)}
    with share_graph(g, include_features=True, tables=tables) as store:
        att = attach(store.handle)
        assert att.graph.num_nodes == g.num_nodes
        assert att.graph.target_type == g.target_type
        assert att.graph.num_classes == g.num_classes
        assert set(att.graph.relations) == set(g.relations)
        for r, csr in g.relations.items():
            np.testing.assert_array_equal(csr.indptr, att.graph.relations[r].indptr)
            np.testing.assert_array_equal(csr.indices, att.graph.relations[r].indices)
            assert att.graph.relations[r].indices.dtype == csr.indices.dtype
        np.testing.assert_array_equal(g.labels, att.graph.labels)
        np.testing.assert_array_equal(g.train_nodes, att.graph.train_nodes)
        for t, f in g.features.items():
            np.testing.assert_array_equal(f, att.graph.features[t])
        np.testing.assert_array_equal(tables["paper"], att.tables["paper"])
        att.close()
    assert not live_segments(store.handle.segment)


def test_attached_views_are_zero_copy_and_read_only():
    g = _graph()
    with share_graph(g) as store:
        att = attach(store.handle)
        # mutate through the owner's view; the attached view must see it
        # (same physical memory, not a pickled copy)
        owner_labels = store._array("labels")
        before = int(att.graph.labels[0])
        owner_labels[0] = before + 1
        assert int(att.graph.labels[0]) == before + 1
        owner_labels[0] = before
        # worker-side views are read-only: accidental writes would corrupt
        # the shared graph under every other worker
        with pytest.raises(ValueError):
            att.graph.labels[0] = 0
        att.close()


def test_sampler_on_attached_graph_bit_identical():
    g = _graph()
    spec = _spec(g)
    with share_graph(g) as store:
        att = attach(store.handle)
        s_host = NeighborSampler(g, spec, 8, seed=5)
        s_shm = NeighborSampler(att.graph, spec, 8, seed=5)
        for i in (0, 3, 1):  # out of order on purpose
            a = s_host.batch_at(i, epoch_seed=11)
            b = s_shm.batch_at(i, epoch_seed=11)
            np.testing.assert_array_equal(a.seeds, b.seeds)
            np.testing.assert_array_equal(a.labels, b.labels)
            for la, lb in zip(a.levels, b.levels):
                np.testing.assert_array_equal(la.nids, lb.nids)
                np.testing.assert_array_equal(la.mask, lb.mask)
        att.close()


def test_handle_is_small_and_picklable():
    g = _graph()
    with share_graph(g) as store:
        blob = pickle.dumps(store.handle)
        # the whole point: workers get a handle, never the graph
        assert len(blob) < 10_000
        handle = pickle.loads(blob)
        att = attach(handle)
        np.testing.assert_array_equal(g.labels, att.graph.labels)
        att.close()


def test_unlink_on_close_removes_segment():
    g = _graph()
    store = share_graph(g)
    seg = store.handle.segment
    assert live_segments(seg) == [seg]
    store.unlink()
    assert not live_segments(seg)
    store.unlink()  # idempotent
    with pytest.raises(FileNotFoundError):
        attach(store.handle)


def test_unshareable_dtype_rejected_without_segment():
    g = _graph()
    before = live_segments()
    # object arrays are pointers — meaningless in another process
    bad = {"paper": np.array([[object()]], dtype=object)}
    with pytest.raises(ValueError, match="object dtype"):
        share_graph(g, tables=bad)
    assert live_segments() == before


def test_create_failure_mid_populate_leaks_no_segment(monkeypatch):
    """A failure while populating the segment must close AND unlink it."""
    import repro.graph.shm as shm_mod

    g = _graph()
    before = live_segments()
    calls = []
    orig_copyto = np.copyto

    def exploding_copyto(dst, src, **kw):
        calls.append(1)
        if len(calls) == 3:  # fail part-way through population
            raise RuntimeError("disk full, or something")
        return orig_copyto(dst, src, **kw)

    monkeypatch.setattr(shm_mod.np, "copyto", exploding_copyto)
    with pytest.raises(RuntimeError, match="disk full"):
        share_graph(g)
    monkeypatch.undo()
    assert live_segments() == before


def test_owner_context_manager_unlinks_on_error():
    g = _graph()
    before = live_segments()
    with pytest.raises(RuntimeError, match="boom"):
        with share_graph(g):
            raise RuntimeError("boom")
    assert live_segments() == before
