"""Block-size autotuner (DESIGN.md §8): determinism of the analytic sweep,
schema validation of the committed tuning table, and the resolve_blocks
priority chain (explicit overrides > table > defaults) the dispatch obeys.
"""

import json

import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.ops import (
    DEFAULT_BLOCKS,
    TUNING_TABLE_PATH,
    VMEM_BUDGET_BYTES,
    KernelOptions,
    load_tuning_table,
    lookup_blocks,
    resolve_blocks,
    shape_class,
)


# --------------------------------------------------------------------------
# determinism + the committed table
# --------------------------------------------------------------------------


def test_build_table_is_deterministic():
    """Two analytic sweeps over the default shapes are bit-identical — the
    property that lets CI regenerate and diff the committed table."""
    t1 = autotune.build_table()
    t2 = autotune.build_table()
    assert t1 == t2
    assert t1["mode"] == "analytic" and t1["backend"] == "any"
    assert len(t1["entries"]) == len(autotune.DEFAULT_SHAPES)


def test_committed_table_validates_and_is_current():
    """The committed table passes the CI schema gate AND equals a fresh
    analytic sweep (regeneration is reproducible on any host)."""
    with open(TUNING_TABLE_PATH) as fh:
        committed = json.load(fh)
    autotune.validate_table(committed)
    assert committed == autotune.build_table()


def test_candidates_clamped_deduped_under_budget():
    for op, n, f, d_in, d_out in autotune.DEFAULT_SHAPES:
        cands = autotune.candidates(op, n, f, d_in, d_out)
        assert cands, f"{op} has no candidate under the VMEM budget"
        assert len(set(cands)) == len(cands)
        for bn, bo, bc in cands:
            assert bn <= max(8, n) and bc <= max(8, d_in)
            assert autotune._vmem_bytes(op, n, f, d_in, d_out, bn, bo, bc) \
                <= VMEM_BUDGET_BYTES


def test_analytic_cost_prefers_fewer_grid_steps():
    """Sanity on the model the winners come from: at fixed VMEM-feasible
    candidates, halving the step count must not cost more."""
    op, n, f, di, do = "stacked_mean_linear", 1024, 25, 128, 64
    few = autotune.analytic_cost_us(op, n, f, di, do, 512, 64, 128)
    many = autotune.analytic_cost_us(op, n, f, di, do, 32, 64, 128)
    assert few < many


# --------------------------------------------------------------------------
# validate_table rejections (CI gate behavior)
# --------------------------------------------------------------------------


def _good_entry():
    return {"block_n": 512, "block_out": 64, "block_in": 128,
            "source": "analytic", "cost_us": 1.0}


def _table(entries):
    return {"version": 1, "mode": "analytic", "backend": "any",
            "budget_bytes": VMEM_BUDGET_BYTES, "entries": entries}


GOOD_KEY = "stacked_mean_linear/float32/n1024/f25/di128/do64"


def test_validate_table_rejects_bad_version():
    with pytest.raises(ValueError, match="version"):
        autotune.validate_table({"version": 2, "entries": {}})


def test_validate_table_rejects_malformed_key():
    with pytest.raises(ValueError, match="malformed"):
        autotune.validate_table(_table({"not/a/key": _good_entry()}))


def test_validate_table_rejects_unknown_op():
    key = "stacked_nonsense/float32/n1024/f25/di128/do64"
    with pytest.raises(ValueError, match="unknown op"):
        autotune.validate_table(_table({key: _good_entry()}))


@pytest.mark.parametrize("field,bad", [
    ("block_n", 0), ("block_out", -8), ("block_in", 1.5), ("block_n", None),
])
def test_validate_table_rejects_non_positive_blocks(field, bad):
    e = _good_entry()
    e[field] = bad
    with pytest.raises(ValueError, match=field):
        autotune.validate_table(_table({GOOD_KEY: e}))


def test_validate_table_rejects_bad_source():
    e = _good_entry()
    e["source"] = "vibes"
    with pytest.raises(ValueError, match="source"):
        autotune.validate_table(_table({GOOD_KEY: e}))


def test_validate_table_rejects_over_budget_blocks():
    key = shape_class("stacked_mean_linear", 25600, 25, 1024, 1024)
    e = {"block_n": 25600, "block_out": 1024, "block_in": 1024,
         "source": "analytic", "cost_us": 1.0}
    with pytest.raises(ValueError, match="VMEM"):
        autotune.validate_table(_table({key: e}))


# --------------------------------------------------------------------------
# dispatch respects the table: the resolve_blocks priority chain
# --------------------------------------------------------------------------


def test_shape_class_buckets_n_to_pow2():
    assert shape_class("stacked_mean_linear", 1000, 25, 128, 64) == \
        shape_class("stacked_mean_linear", 1024, 25, 128, 64)
    assert shape_class("stacked_mean_linear", 1025, 25, 128, 64) != \
        shape_class("stacked_mean_linear", 1024, 25, 128, 64)


def test_resolve_blocks_priority_chain(tmp_path):
    """explicit opts.block_* > tuning table (autotune on) > DEFAULT_BLOCKS,
    exercised against a temp table with a distinctive winner."""
    p = tmp_path / "table.json"
    key = shape_class("stacked_mean_linear", 1024, 25, 128, 64)
    autotune.save_table(_table({key: _good_entry()}), p)
    shape = ("stacked_mean_linear", 1024, 25, 128, 64)

    # autotune off -> defaults, even with the table present
    off = KernelOptions(autotune=False)
    assert resolve_blocks(off, *shape, path=str(p)) == DEFAULT_BLOCKS

    # autotune on -> the table's winner
    on = KernelOptions(autotune=True)
    assert resolve_blocks(on, *shape, path=str(p)) == (512, 64, 128)

    # table miss -> defaults
    miss = ("stacked_mean_linear", 64, 3, 8, 8)
    assert resolve_blocks(on, *miss, path=str(p)) == DEFAULT_BLOCKS

    # explicit overrides beat the table where set, table fills the rest
    ov = KernelOptions(autotune=True, block_n=64)
    assert resolve_blocks(ov, *shape, path=str(p)) == (64, 64, 128)

    # no opts at all -> defaults
    assert resolve_blocks(None, *shape, path=str(p)) == DEFAULT_BLOCKS


def test_lookup_blocks_committed_table_hit():
    """The committed table serves the mag_l1 shape class the benchmarks
    race (BENCH_kernels.json's autotuned rows)."""
    hit = lookup_blocks("stacked_mean_linear", 1024, 25, 128, 64)
    assert hit is not None
    bn, bo, bc = hit
    assert all(isinstance(v, int) and v > 0 for v in (bn, bo, bc))


def test_save_table_round_trips_and_clears_cache(tmp_path):
    p = tmp_path / "t.json"
    table = _table({GOOD_KEY: _good_entry()})
    autotune.save_table(table, p)
    assert load_tuning_table(str(p)) == table
    # overwrite with an empty table: the lru cache must not serve stale hits
    autotune.save_table(_table({}), p)
    assert load_tuning_table(str(p))["entries"] == {}


def test_stacked_agg_dispatch_consults_table(monkeypatch, tmp_path):
    """End to end: with opts.autotune on, the stacked_agg dispatch resolves
    its blocks through the table (observed via the resolver call) and the
    numerics stay oracle-equal regardless of the block choice."""
    import jax.numpy as jnp

    from repro.core.relmod import get_relation_module
    from repro.kernels.stacked_relation_agg import (
        ops as sops,
        stacked_agg,
        stacked_agg_ref,
    )

    seen = []
    real = sops.resolve_blocks

    def spy(opts, op, n, f, d_in, d_out, path=None):
        out = real(opts, op, n, f, d_in, d_out, path=path)
        seen.append((op, out))
        return out

    monkeypatch.setattr(sops, "resolve_blocks", spy)

    mod = get_relation_module("rgcn")
    r = np.random.default_rng(3)
    rb, n, f, di, do, U = 4, 40, 3, 16, 12, 2
    stacks = {"w": jnp.asarray(r.standard_normal((U, di, do)), jnp.float32),
              "b": jnp.asarray(r.standard_normal((U, do)), jnp.float32)}
    slot_u = {"relation": jnp.asarray(r.integers(0, U, rb))}
    h = jnp.asarray(r.standard_normal((rb, n, f, di)), jnp.float32)
    q = jnp.asarray(r.standard_normal((rb, n, di)), jnp.float32)
    mask = jnp.asarray(r.random((rb, n, f)) > 0.3)

    opts = KernelOptions(interpret=True, autotune=True)
    out = stacked_agg(mod, stacks, slot_u, h, q, mask, opts=opts)
    ref = stacked_agg_ref(mod, stacks, slot_u, h, q, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    assert seen and seen[0][0] == "stacked_mean_linear"
