"""Sampler invariants: static shapes, masks, index validity, determinism."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.metatree import build_metatree
from repro.graph.hetgraph import CSR, Relation
from repro.graph.sampler import NeighborSampler, SampleSpec, sample_neighbors
from repro.graph.synthetic import make_dataset, ogbn_mag_like


@pytest.fixture(scope="module")
def setup():
    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    spec = SampleSpec.from_metatree(tree, [5, 4])
    return g, spec


def test_static_shapes(setup):
    g, spec = setup
    sampler = NeighborSampler(g, spec, 8, seed=0)
    b = sampler.sample_batch(g.train_nodes[:8])
    n = {d: 8 for d in range(3)}
    n[1] = 8 * 5
    n[2] = 8 * 5 * 4
    for d, lv in enumerate(b.levels, start=1):
        assert lv.nids.shape == (len(spec.levels[d - 1]), n[d])
        assert lv.mask.shape == lv.nids.shape


def test_indices_within_type_range(setup):
    g, spec = setup
    sampler = NeighborSampler(g, spec, 16, seed=1)
    b = sampler.sample_batch(g.train_nodes[:16])
    for lv, branches in zip(b.levels, spec.levels):
        for i, bs in enumerate(branches):
            assert lv.nids[i].max() < g.num_nodes[bs.src_type]
            assert lv.nids[i].min() >= 0


def test_sampled_are_real_neighbors(setup):
    """Every unmasked sample must be an actual in-neighbor under the branch's
    relation."""
    g, spec = setup
    sampler = NeighborSampler(g, spec, 4, seed=2)
    b = sampler.sample_batch(g.train_nodes[:4])
    lv = b.levels[0]
    for i, bs in enumerate(spec.levels[0]):
        csr = g.relations[bs.rel]
        f = spec.fanouts[0]
        for parent_pos, parent in enumerate(b.seeds):
            nbrs = set(csr.indices[csr.indptr[parent]:csr.indptr[parent + 1]])
            for j in range(f):
                slot = parent_pos * f + j
                if lv.mask[i, slot]:
                    assert lv.nids[i, slot] in nbrs


def test_mask_false_iff_zero_degree_chain(setup):
    g, spec = setup
    sampler = NeighborSampler(g, spec, 8, seed=3)
    b = sampler.sample_batch(g.train_nodes[:8])
    lv1 = b.levels[0]
    for i, bs in enumerate(spec.levels[0]):
        deg = g.relations[bs.rel].degrees()[b.seeds]
        expect = np.repeat(deg > 0, spec.fanouts[0])
        np.testing.assert_array_equal(lv1.mask[i], expect)


def test_epoch_covers_train_nodes(setup):
    g, spec = setup
    sampler = NeighborSampler(g, spec, 64, seed=4)
    seen = []
    for b in sampler.epoch(shuffle=True, seed=9):
        seen.append(b.seeds)
    seen = np.concatenate(seen)
    assert len(seen) == sampler.steps_per_epoch() * 64
    assert len(np.unique(seen)) == len(seen)  # no duplicates within an epoch


@given(
    num_src=st.integers(1, 50),
    num_dst=st.integers(1, 50),
    num_edges=st.integers(0, 200),
    fanout=st.integers(1, 8),
    seed=st.integers(0, 999),
)
@settings(max_examples=30, deadline=None)
def test_sample_neighbors_property(num_src, num_dst, num_edges, fanout, seed):
    rng = np.random.default_rng(seed)
    if num_edges:
        csr = CSR.from_edges(
            rng.integers(0, num_src, num_edges), rng.integers(0, num_dst, num_edges),
            num_dst,
        )
    else:
        csr = CSR(np.zeros(num_dst + 1, np.int64), np.zeros(0, np.int64))
    parents = rng.integers(0, num_dst, 7)
    pm = np.ones(7, bool)
    idx, mask = sample_neighbors(csr, parents, pm, fanout, rng)
    assert idx.shape == (7, fanout) and mask.shape == (7, fanout)
    deg = csr.degrees()[parents]
    np.testing.assert_array_equal(mask.all(axis=1), deg > 0)
    if num_edges:
        assert idx.max() < num_src


def test_all_datasets_sample():
    for name in ("ogbn-mag", "freebase", "donor", "igb-het", "mag240m"):
        g = make_dataset(name)
        tree = build_metatree(g.metagraph(), g.target_type, 2)
        spec = SampleSpec.from_metatree(tree, [3, 2])
        sampler = NeighborSampler(g, spec, 4, seed=0)
        b = sampler.sample_batch(g.train_nodes[:4])
        assert b.total_sampled() > 4
