"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (≤2 periods, d_model ≤ 512, ≤4 experts) runs one forward/train
step on CPU asserting output shapes + no NaNs, plus decode-vs-forward cache
consistency for decoder architectures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs.all_archs  # noqa: F401
from repro.configs.base import ARCHS, INPUT_SHAPES
from repro.launch.specs import plan_step
from repro.models import (
    forward,
    init_decode_cache,
    init_params,
    init_train_state,
    loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

ALL_ARCHS = sorted(ARCHS)
rng = np.random.default_rng(0)


def _batch(cfg, B=2, S=64):
    if cfg.frontend == "audio":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.frontend_dim)), jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        }
    if cfg.frontend == "vision":
        P = cfg.frontend_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - P))),
            "patch_embeds": jnp.asarray(
                rng.standard_normal((B, P, cfg.frontend_dim)), jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - P))),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
    }


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_constraints(name):
    r = ARCHS[name].reduced()
    assert r.d_model <= 512
    assert r.n_periods <= 2
    assert r.moe_experts <= 4
    assert r.family == ARCHS[name].family


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = ARCHS[name].reduced()
    batch = _batch(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits = forward(cfg, params, batch)
    S = 64
    assert logits.shape == (2, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_decreases_loss(name):
    cfg = ARCHS[name].reduced()
    batch = _batch(cfg)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, donate=False)
    losses = []
    for _ in range(4):
        state, loss = step(state, batch)
        assert np.isfinite(float(loss))
        losses.append(float(loss))
    assert losses[-1] < losses[0]  # overfits the fixed batch


DECODERS = [n for n in ALL_ARCHS if ARCHS[n].is_decoder]


@pytest.mark.parametrize("name", DECODERS)
def test_decode_matches_forward(name):
    """Cache correctness: prefill(tokens[:t]) then decode(token t) must match
    the full forward's last-position logits (dense KV + mamba state paths).

    MoE capacity is raised so no tokens drop: with finite capacity the
    prefill (many tokens per routing group) drops tokens the single-token
    decode keeps — inherent capacity-MoE semantics, not a cache bug."""
    import dataclasses

    cfg = dataclasses.replace(ARCHS[name].reduced(), capacity_factor=64.0)
    if cfg.frontend == "vision":
        pytest.skip("vlm decode covered by shape test; prefill mixes patches")
    B, S = 2, 32
    toks = rng.integers(0, cfg.vocab, (B, S + 1))
    params = init_params(cfg, jax.random.PRNGKey(0))
    full = forward(cfg, params, {"tokens": jnp.asarray(toks)}, remat=False)

    prefill = make_prefill_step(cfg)
    logits_p, cache = prefill(params, {"tokens": jnp.asarray(toks[:, :S])})
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full[:, S - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    # the prefill cache is sized to S; decode needs one more slot
    if "k" in cache:
        pad = [(0, 0)] * 6
        pad[3] = (0, 1)
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)
    serve = make_serve_step(cfg, donate=False)
    logits_d, _ = serve(
        params, cache, jnp.asarray(toks[:, S : S + 1]), jnp.asarray(S, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full[:, S], np.float32),
        atol=2e-2, rtol=2e-2,
    )


@pytest.mark.parametrize("name", DECODERS)
def test_sliding_window_decode_runs(name):
    cfg = ARCHS[name].reduced()
    if not cfg.attn_slots:
        pytest.skip("attention-free")
    params = init_params(cfg, jax.random.PRNGKey(0))
    W = 16
    cache = init_decode_cache(cfg, 2, W)
    serve = make_serve_step(cfg, window=W, donate=False)
    # decode past the window boundary: ring buffer wraps
    logits = None
    for pos in [0, 1, W - 1, W, W + 3]:
        logits, cache = serve(
            params, cache, jnp.zeros((2, 1), jnp.int32), jnp.asarray(pos, jnp.int32)
        )
    assert bool(jnp.isfinite(logits).all())


def test_shape_plan_skips():
    """hubert is encoder-only: decode shapes are skipped with a reason; dense
    archs get the sliding-window plan at 500k (DESIGN.md §4)."""
    hub = ARCHS["hubert-xlarge"]
    assert plan_step(hub, INPUT_SHAPES["decode_32k"]).kind == "skip"
    assert plan_step(hub, INPUT_SHAPES["long_500k"]).kind == "skip"
    llama = ARCHS["llama3.2-3b"]
    p = plan_step(llama, INPUT_SHAPES["long_500k"])
    assert p.kind == "decode" and p.window == 8192
    mamba = ARCHS["mamba2-1.3b"]
    p = plan_step(mamba, INPUT_SHAPES["long_500k"])
    assert p.kind == "decode" and p.window is None  # native sub-quadratic


def test_param_counts_match_advertised_scale():
    expect = {
        "llama3.2-3b": (3.0e9, 4.5e9),
        "yi-6b": (5.5e9, 6.6e9),
        "jamba-1.5-large-398b": (3.5e11, 4.4e11),
        "mamba2-1.3b": (1.2e9, 1.6e9),
        "llava-next-34b": (3.2e10, 3.6e10),
        "qwen3-moe-30b-a3b": (2.8e10, 3.2e10),
        "qwen2-1.5b": (1.4e9, 2.0e9),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
        "hubert-xlarge": (0.9e9, 1.4e9),
        "chatglm3-6b": (5.6e9, 6.8e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
    # MoE active params: qwen3 "A3B" ≈ 3B active
    a = ARCHS["qwen3-moe-30b-a3b"].active_param_count()
    assert 2.5e9 <= a <= 4.0e9
