"""Property-test shim: real ``hypothesis`` when installed, a deterministic
fallback otherwise.

The container image does not ship ``hypothesis``; without this shim the four
property-test modules error at import and kill the whole tier-1 collection.
Test modules import ``given`` / ``settings`` / ``st`` from here:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real thing.  Without it, ``@given``
runs the test body on a small fixed set of examples drawn from seeded
``numpy`` RNGs — no shrinking, no database, but the same strategy surface
(``st.integers``, ``st.sampled_from``, ``@st.composite``) and deterministic
across runs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _FALLBACK_EXAMPLES = 10  # examples per @given test (capped at max_examples)

    class _Strategy:
        """A draw function ``rng -> value``."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng):
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _Strategy(
                    lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs)
                )

            return make

    st = _strategies

    def settings(max_examples=None, deadline=None, **_ignored):
        """Records max_examples for the fallback runner; otherwise a no-op."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            declared = getattr(fn, "_compat_max_examples", None)
            n = min(declared or _FALLBACK_EXAMPLES, _FALLBACK_EXAMPLES)

            def runner():
                for i in range(n):
                    rng = _np.random.default_rng(0xE7A ^ (7919 * (i + 1)))
                    args = [s.draw(rng) for s in arg_strategies]
                    kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # plain zero-arg function: no functools.wraps, so pytest does not
            # follow __wrapped__ and mistake strategy params for fixtures
            runner.__name__ = fn.__name__
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
