"""End-to-end system tests: the full Heta pipeline on one device, comm
accounting sanity, checkpoint round-trips, and the sharding rule tables."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import vanilla_comm_bytes, vanilla_update_bytes
from repro.core.meta_partition import EdgeCutPartition, meta_partition, random_edge_cut
from repro.core.metatree import build_metatree
from repro.graph.hetgraph import CSR, HetGraph, Relation
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import ogbn_mag_like
from repro.launch.train import train_hgnn


def test_full_pipeline_single_device():
    """partition → presample → cache → SPMD RAF train → learnable updates."""
    m = train_hgnn(
        dataset="ogbn-mag", scale=0.002, model="rgcn", num_partitions=2,
        mesh_shape=(1, 1), batch_size=16, fanouts=(4, 3), steps=5, cache_mb=2,
    )
    assert m["meta_local"]
    assert all(np.isfinite(m["losses"]))
    assert any(v > 0 for v in m["hit_rates"].values())


def test_full_pipeline_featureless():
    """Freebase-like: every node type learnable (paper's hardest cache case)."""
    m = train_hgnn(
        dataset="freebase", scale=0.0005, model="rgcn", num_partitions=2,
        mesh_shape=(1, 1), batch_size=8, fanouts=(3, 2), steps=3, cache_mb=2,
    )
    assert all(np.isfinite(m["losses"]))


def test_naive_placement_still_correct():
    m = train_hgnn(
        dataset="ogbn-mag", scale=0.002, model="rgcn", num_partitions=2,
        mesh_shape=(1, 1), batch_size=8, fanouts=(3, 2), steps=3,
        naive_placement=True,
    )
    assert not m["meta_local"]
    assert all(np.isfinite(m["losses"]))


# --------------------------------------------------------------------------
# vanilla comm accounting on a hand-built graph
# --------------------------------------------------------------------------


def _toy_graph():
    # 2 types: u (4 nodes, feat dim 8) -> v (2 target nodes)
    rel = Relation("u", "e", "v")
    csr = CSR.from_edges(np.array([0, 1, 2, 3]), np.array([0, 0, 1, 1]), 2)
    return HetGraph(
        num_nodes={"u": 4, "v": 2},
        relations={rel: csr},
        target_type="v",
        num_classes=2,
        features={"u": np.zeros((4, 8), np.float32),
                  "v": np.zeros((2, 4), np.float32)},
    )


def test_vanilla_comm_exact_count():
    g = _toy_graph()
    tree = build_metatree(g.metagraph(), "v", 1)
    spec = SampleSpec.from_metatree(tree, [2])
    sampler = NeighborSampler(g, spec, 2, seed=0)
    b = sampler.sample_batch(np.array([0, 1]))
    # seed 0 on partition 0, seed 1 on partition 1; u nodes 0,1 on 0; 2,3 on 1
    cut = EdgeCutPartition(
        assignment={"v": np.array([0, 1], np.int32),
                    "u": np.array([0, 0, 1, 1], np.int32)},
        num_partitions=2,
    )
    feat_dims = {"u": 8, "v": 4}
    got = vanilla_comm_bytes(b, cut, feat_dims, bytes_per_elem=2,
                             include_topology=False)
    # neighbors of v0 are u{0,1} (local to part 0) and of v1 are u{2,3}
    # (local to part 1): zero remote fetches
    assert got == 0
    # flip the u assignment: every fetch is remote; unique remote u per seed ≤ 2
    cut2 = EdgeCutPartition(
        assignment={"v": np.array([0, 1], np.int32),
                    "u": np.array([1, 1, 0, 0], np.int32)},
        num_partitions=2,
    )
    got2 = vanilla_comm_bytes(b, cut2, feat_dims, bytes_per_elem=2,
                              include_topology=False)
    uniq = 0
    for seed_pos, seed in enumerate(b.seeds):
        ids = set(b.levels[0].nids[0][seed_pos * 2:(seed_pos + 1) * 2])
        uniq += len(ids)
    assert got2 == uniq * 8 * 2


def test_update_bytes_zero_when_no_learnable():
    g = _toy_graph()
    tree = build_metatree(g.metagraph(), "v", 1)
    spec = SampleSpec.from_metatree(tree, [2])
    b = NeighborSampler(g, spec, 2, seed=0).sample_batch(np.array([0, 1]))
    cut = random_edge_cut(g, 2)
    assert vanilla_update_bytes(b, cut, g) == 0  # all types featured


# --------------------------------------------------------------------------
# checkpoint round-trip
# --------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.asarray(7)},
    }
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    assert latest_step(str(tmp_path)) == 9
    restored = load_checkpoint(str(tmp_path), 9, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# sharding rule tables (AbstractMesh: no devices needed)
# --------------------------------------------------------------------------


def test_param_pspecs_divide_on_production_mesh():
    import repro.configs.all_archs  # noqa: F401
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import ARCHS
    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import param_pspecs
    from repro.launch.specs import abstract_params

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    for name, cfg in sorted(ARCHS.items()):
        params = abstract_params(cfg)
        specs = param_pspecs(cfg, params, mesh)
        flat_p = jax.tree_util.tree_leaves_with_path(params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                size = mesh.shape[ax] if isinstance(ax, str) else int(
                    np.prod([mesh.shape[a] for a in ax])
                )
                assert dim % size == 0, f"{name} {path} {leaf.shape} {spec}"


def test_cache_pspecs_long_context():
    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS, INPUT_SHAPES
    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import cache_pspecs
    from repro.launch.specs import abstract_cache

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    cfg = ARCHS["jamba-1.5-large-398b"]
    cache = abstract_cache(cfg, INPUT_SHAPES["long_500k"])
    specs = cache_pspecs(cfg, cache, mesh)
    # batch-1: sequence axis spread over (data, model)
    assert specs["k"][3] == ("data", "model")
    assert specs["ssm"][3] == "model"
