"""Meta-partitioning (paper §5, Algorithm 2) + Prop 2/3 property tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.meta_partition import (
    boundary_nodes,
    cross_edges,
    greedy_edge_cut,
    meta_partition,
    random_edge_cut,
)
from repro.core.metatree import build_metatree, build_metatree_from_metapaths
from repro.graph.hetgraph import CSR, HetGraph, Relation
from repro.graph.synthetic import donor_like, freebase_like, ogbn_mag_like


@pytest.fixture(scope="module")
def mag():
    return ogbn_mag_like(scale=0.002, seed=0)


def test_metatree_matches_paper_figure(mag):
    """ogbn-mag's 2-hop metatree has 3 root children (writes, rev_has_topic,
    cites) — paper Fig. 6 Step 1."""
    tree = build_metatree(mag.metagraph(), "paper", 2)
    etypes = sorted(c.rel.etype for c in tree.children)
    assert etypes == ["cites", "rev_has_topic", "writes"]
    assert tree.max_depth() == 2


def test_metatree_from_metapaths(mag):
    meta = mag.metagraph()
    pap = Relation("author", "writes", "paper")
    aui = Relation("institution", "rev_affiliated_with", "author")
    tree = build_metatree_from_metapaths(meta, "paper", [[pap, aui], [pap]])
    assert len(tree.children) == 1  # shared prefix merged
    assert tree.children[0].children[0].rel == aui


def test_partitions_all_contain_target_nodes(mag):
    """§5 Step 2: every partition holds ALL target nodes, confining boundary
    nodes to the target type."""
    mp = meta_partition(mag, 2, num_layers=2)
    for p in mp.partitions:
        assert "paper" in p.graph.num_nodes
        assert p.graph.num_nodes["paper"] == mag.num_nodes["paper"]
    assert mp.max_boundary_nodes() == mag.num_nodes["paper"]


def test_partitions_cover_metatree_relations(mag):
    mp = meta_partition(mag, 2, num_layers=2)
    tree_rels = set(build_metatree(mag.metagraph(), "paper", 2).relations())
    part_rels = set()
    for p in mp.partitions:
        part_rels.update(p.relations)
    assert part_rels == tree_rels


def test_partition_subgraphs_are_complete_mono_relation(mag):
    """§5 Step 4: each partition materializes COMPLETE mono-relation
    subgraphs (same edge counts as the full graph)."""
    mp = meta_partition(mag, 2, num_layers=2)
    for p in mp.partitions:
        for rel in p.relations:
            assert p.graph.relations[rel].num_edges == mag.relations[rel].num_edges


def test_dedup_within_partition(mag):
    mp = meta_partition(mag, 1, num_layers=2)
    rels = mp.partitions[0].relations
    assert len(rels) == len(set(rels))


def test_lpt_balance(mag):
    """LPT assignment: max load ≤ 2× min load on this schema (greedy bound)."""
    mp = meta_partition(mag, 2, num_layers=2)
    weights = [p.weight for p in mp.partitions]
    assert max(weights) <= 2 * max(min(weights), 1)


def test_replication_when_more_partitions_than_subtrees(mag):
    mp = meta_partition(mag, 8, num_layers=2)
    assert mp.replicated
    assert len(mp.partitions) == 8
    # replicas share a replica_group
    groups = {}
    for p in mp.partitions:
        groups.setdefault(p.replica_group, []).append(p.index)
    assert any(len(v) > 1 for v in groups.values())


def test_meta_partitioning_is_metagraph_sized(mag):
    """Complexity claim: partitioning time must not scale with graph size —
    it runs on the metagraph (paper Table 2: 20.6 min vs hours)."""
    mp = meta_partition(mag, 2, num_layers=2, materialize=False)
    assert mp.elapsed_s < 0.5  # milliseconds in practice


def test_works_on_all_schemas():
    for g in (freebase_like(scale=0.0005), donor_like(scale=0.001)):
        mp = meta_partition(g, 4, num_layers=2)
        assert len(mp.partitions) == 4
        total = set()
        for p in mp.partitions:
            total.update(p.relations)
        assert total  # non-empty coverage


# --------------------------------------------------------------------------
# Prop 3: max boundary nodes ≤ cross-partition edges (property-based)
# --------------------------------------------------------------------------


@st.composite
def _random_hetg(draw):
    n_types = draw(st.integers(2, 4))
    types = [f"t{i}" for i in range(n_types)]
    num_nodes = {t: draw(st.integers(4, 40)) for t in types}
    n_rels = draw(st.integers(1, 5))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    relations = {}
    for i in range(n_rels):
        src = draw(st.sampled_from(types))
        dst = draw(st.sampled_from(types))
        ne = draw(st.integers(1, 120))
        s = rng.integers(0, num_nodes[src], ne)
        d = rng.integers(0, num_nodes[dst], ne)
        relations[Relation(src, f"e{i}", dst)] = CSR.from_edges(s, d, num_nodes[dst])
    # ensure the target type has at least one in-relation
    tgt = next(iter(relations)).dst
    return HetGraph(
        num_nodes=num_nodes, relations=relations, target_type=tgt, num_classes=2
    )


@given(_random_hetg(), st.integers(2, 4), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_prop3_boundary_leq_cross_edges(graph, p, seed):
    cut = random_edge_cut(graph, p, seed=seed)
    b = boundary_nodes(graph, cut)
    e = cross_edges(graph, cut)
    # Prop 3: max_i |B(G_i)| ≤ E(cross) — each cross edge contributes at most
    # one boundary node to each partition
    assert max(b) <= e if e else max(b) == 0


@given(_random_hetg(), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_greedy_cut_no_worse_than_random_on_avg(graph, seed):
    """LDG-style greedy should not exceed random cut size by much (sanity of
    the METIS stand-in)."""
    rc = cross_edges(graph, random_edge_cut(graph, 2, seed))
    gc = cross_edges(graph, greedy_edge_cut(graph, 2, seed))
    total = sum(c.num_edges for c in graph.relations.values())
    assert gc <= max(rc, int(0.9 * total) + 1)
