"""WorkerPool: stripe ordering, determinism vs the serial sampler,
exception propagation from worker processes, and lifecycle (close joins,
idempotence, no stray processes or shm segments)."""

import dataclasses
import os
import time

import numpy as np
import pytest

from repro.core.metatree import build_metatree
from repro.data.worker_pool import (
    EpochSchedule,
    SampleStageTask,
    WorkerPool,
)
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.shm import live_segments, share_graph
from repro.graph.synthetic import ogbn_mag_like

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="worker pool tests rely on /dev/shm"
)


# task classes live at module level so spawn can unpickle them in workers


@dataclasses.dataclass
class SquareTask:
    def setup(self):
        pass

    def __call__(self, i):
        return i * i

    def teardown(self):
        pass


@dataclasses.dataclass
class FailAtTask:
    fail_at: int

    def setup(self):
        pass

    def __call__(self, i):
        if i == self.fail_at:
            raise ZeroDivisionError(f"boom at {i}")
        return i

    def teardown(self):
        pass


@dataclasses.dataclass
class BadSetupTask:
    def setup(self):
        raise OSError("no graph for you")

    def __call__(self, i):  # pragma: no cover — setup always fails
        return i

    def teardown(self):
        pass


@pytest.mark.parametrize("num_workers", [1, 2, 3])
def test_strict_order_and_finite_stop(num_workers):
    with WorkerPool(SquareTask(), num_workers=num_workers, depth=2,
                    num_items=7) as pool:
        assert list(pool) == [i * i for i in range(7)]
        with pytest.raises(StopIteration):
            next(pool)


def test_worker_exception_propagates_and_pool_closes():
    pool = WorkerPool(FailAtTask(fail_at=3), num_workers=2, depth=1,
                      num_items=10)
    got = []
    with pytest.raises(ZeroDivisionError, match="boom at 3"):
        for x in pool:
            got.append(x)
    assert got == [0, 1, 2]  # everything before the failure, in order
    assert all(not p.is_alive() for p in pool._procs)
    with pytest.raises(RuntimeError, match="closed"):
        next(pool)


def test_setup_failure_propagates():
    pool = WorkerPool(BadSetupTask(), num_workers=2, num_items=4)
    with pytest.raises(OSError, match="no graph for you"):
        list(pool)
    assert all(not p.is_alive() for p in pool._procs)


def test_close_joins_and_is_idempotent():
    pool = WorkerPool(SquareTask(), num_workers=2, depth=1)  # infinite stripe
    assert next(pool) == 0
    pool.close()
    assert all(not p.is_alive() for p in pool._procs)
    pool.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        next(pool)


def test_validation():
    with pytest.raises(ValueError, match="num_workers"):
        WorkerPool(SquareTask(), num_workers=0)
    with pytest.raises(ValueError, match="depth"):
        WorkerPool(SquareTask(), num_workers=1, depth=0)


# --------------------------------------------------------------------------
# SampleStageTask — the HGNN sampling task over the shm store
# --------------------------------------------------------------------------


def _mag():
    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    return g, SampleSpec.from_metatree(tree, [3, 2])


def _assert_batches_equal(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(a.labels, b.labels)
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(la.nids, lb.nids)
        np.testing.assert_array_equal(la.mask, lb.mask)


def test_epoch_schedule_matches_session_formula():
    sched = EpochSchedule(epoch_seed_base=42, steps_per_epoch=5, start_step=3)
    # global step 3+9=12 -> epoch 2, index 2, seed base + 2*5
    assert sched.seed_and_index(9) == (42 + 10, 2)
    assert sched.seed_and_index(0) == (42, 3)


@pytest.mark.parametrize("num_workers", [1, 3])
def test_pool_batches_bit_identical_to_serial(num_workers):
    g, spec = _mag()
    serial = NeighborSampler(g, spec, 8, seed=5)
    E = serial.steps_per_epoch()
    store = share_graph(g, include_features=False)
    try:
        task = SampleStageTask(
            handle=store.handle, spec=spec, batch_size=8, sampler_seed=5,
            schedule=EpochSchedule(77, E),
        )
        n = min(E + 2, 6)  # cross an epoch boundary when the graph allows
        with WorkerPool(task, num_workers=num_workers, depth=2,
                        num_items=n) as pool:
            for i, (batch, host, host_s) in enumerate(pool):
                seed, idx = EpochSchedule(77, E).seed_and_index(i)
                _assert_batches_equal(batch, serial.batch_at(idx, epoch_seed=seed))
                assert host is None and host_s >= 0.0
    finally:
        store.unlink()
    assert not live_segments(store.handle.segment)


def test_worker_staging_matches_consumer_staging():
    """The recipe path: a worker-staged frozen-table batch must be
    bit-identical to staging the same batch on the consumer (both run
    repro.data.staging.stack_batch_host)."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.hgnn import HGNNConfig
    from repro.core.meta_partition import meta_partition
    from repro.core.raf import assign_branches
    from repro.core import raf_spmd
    from repro.data.staging import stack_batch_host

    g, _ = _mag()
    mp_ = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp_.metatree, [3, 2])
    assignment = assign_branches(spec, mp_)
    cfg = HGNNConfig(model="rgcn", hidden=32, num_layers=2, num_heads=4,
                     num_classes=g.num_classes, learnable_dim=16)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    plan = raf_spmd.build_plan(spec, assignment, cfg, feat_dims)
    recipe = raf_spmd.stack_recipe(plan)

    rng = np.random.default_rng(0)
    tables = {
        t: (g.features[t].astype(np.float32) if t in g.features
            else rng.standard_normal((g.num_nodes[t], 16)).astype(np.float32))
        for t in g.num_nodes
    }
    serial = NeighborSampler(g, spec, 8, seed=5)
    store = share_graph(g, include_features=False, tables=tables)
    try:
        task = SampleStageTask(
            handle=store.handle, spec=spec, batch_size=8, sampler_seed=5,
            schedule=EpochSchedule(9, serial.steps_per_epoch()), recipe=recipe,
        )
        with WorkerPool(task, num_workers=2, depth=2, num_items=3) as pool:
            for i, (batch, host, _) in enumerate(pool):
                assert host is not None
                ref = stack_batch_host(
                    recipe, serial.batch_at(i, epoch_seed=9), tables)
                assert set(host) == set(ref)
                for k in ref:
                    np.testing.assert_array_equal(host[k], ref[k])
                # and the full executor path gives the same device arrays
                dev = raf_spmd.stack_batch(plan, batch, tables)
                for k in ref:
                    np.testing.assert_array_equal(np.asarray(dev[k]), ref[k])
    finally:
        store.unlink()


def test_pool_shutdown_leaves_no_processes_quickly():
    g, spec = _mag()
    store = share_graph(g, include_features=False)
    try:
        task = SampleStageTask(
            handle=store.handle, spec=spec, batch_size=8, sampler_seed=0,
            schedule=EpochSchedule(0, NeighborSampler(g, spec, 8).steps_per_epoch()),
        )
        pool = WorkerPool(task, num_workers=2, depth=1)  # infinite
        next(pool)
        t0 = time.perf_counter()
        pool.close()
        assert time.perf_counter() - t0 < 10.0
        assert all(not p.is_alive() for p in pool._procs)
    finally:
        store.unlink()
