"""Host data pipelines: Prefetcher lifecycle, token pipeline determinism /
sharding / liveness, per-batch sampler RNG, and the async SampleStream."""

import threading
import time

import numpy as np
import pytest

from repro.data import Prefetcher, SampleStream, SyntheticCorpus, TokenPipeline


def test_corpus_deterministic_and_shifted():
    c = SyntheticCorpus(vocab=1000, seq_len=32, num_shards=4, seed=7)
    a = c.sequence(1, 5)
    b = c.sequence(1, 5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(c.sequence(1, 6), a)
    batch = c.batch(0, 0, 4)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])
    assert batch["tokens"].max() < 1000


def test_pipeline_shapes_and_progress():
    c = SyntheticCorpus(vocab=512, seq_len=16, num_shards=4)
    pipe = TokenPipeline(c, global_batch=8, prefetch=2)
    try:
        b1 = next(pipe)
        b2 = next(pipe)
        assert b1["tokens"].shape == (8, 16)
        assert b1["labels"].shape == (8, 16)
        assert not np.array_equal(b1["tokens"], b2["tokens"])
    finally:
        pipe.close()


def test_pipeline_multi_host_split():
    c = SyntheticCorpus(vocab=512, seq_len=16, num_shards=4)
    p0 = TokenPipeline(c, global_batch=8, host_id=0, num_hosts=2)
    p1 = TokenPipeline(c, global_batch=8, host_id=1, num_hosts=2)
    try:
        b0, b1 = next(p0), next(p1)
        assert b0["tokens"].shape == (4, 16)  # half the global batch each
        assert not np.array_equal(b0["tokens"], b1["tokens"])  # disjoint shards
    finally:
        p0.close()
        p1.close()


def test_pipeline_feeds_training():
    import jax

    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS
    from repro.models import init_train_state, make_train_step

    cfg = ARCHS["qwen2-1.5b"].reduced()
    c = SyntheticCorpus(vocab=cfg.vocab, seq_len=32, num_shards=2)
    pipe = TokenPipeline(c, global_batch=2)
    try:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, donate=False)
        import jax.numpy as jnp

        for _ in range(2):
            b = next(pipe)
            state, loss = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            assert np.isfinite(float(loss))
    finally:
        pipe.close()


# --------------------------------------------------------------------------
# Prefetcher — the shared producer (lifecycle contract)
# --------------------------------------------------------------------------


def test_prefetcher_order_and_finite_stop():
    with Prefetcher(lambda i: i * i, depth=2, num_items=5) as pf:
        assert list(pf) == [0, 1, 4, 9, 16]
        with pytest.raises(StopIteration):  # exhausted stays exhausted
            next(pf)


def test_prefetcher_close_joins_and_next_raises():
    pf = Prefetcher(lambda i: i, depth=2)
    assert next(pf) == 0
    pf.close()
    assert not pf._thread.is_alive()  # producer actually joined
    with pytest.raises(RuntimeError, match="closed"):
        next(pf)
    pf.close()  # idempotent


def test_prefetcher_producer_exception_propagates():
    def make(i):
        if i == 2:
            raise ZeroDivisionError("boom at 2")
        return i

    pf = Prefetcher(make, depth=1)
    assert next(pf) == 0
    assert next(pf) == 1
    with pytest.raises(ZeroDivisionError, match="boom at 2"):
        # drain until the failure surfaces (depth may buffer good items)
        for _ in range(10):
            next(pf)
    assert not pf._thread.is_alive()  # failure also shuts the producer down
    with pytest.raises(RuntimeError, match="closed"):
        next(pf)


def test_prefetcher_close_unblocks_full_queue_producer():
    """close() must join even while the producer is blocked on a full queue."""
    pf = Prefetcher(lambda i: i, depth=1)
    time.sleep(0.1)  # let the producer fill the queue and block on put
    pf.close()
    assert not pf._thread.is_alive()


def test_prefetcher_close_idempotent_after_producer_failure():
    """A failure already shut the stream down from __next__; every later
    close() — explicit, context exit, or GC — must be a silent no-op."""
    def make(i):
        raise ValueError("dead on arrival")

    pf = Prefetcher(make, depth=1)
    with pytest.raises(ValueError, match="dead on arrival"):
        next(pf)
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pf.close()
        pf.close(warn=False)
        pf.__del__()  # the GC path must never raise or warn
    assert not pf._thread.is_alive()


def test_prefetcher_del_mid_run_is_quiet():
    """GC'ing a live stream (no explicit close) joins the thread without
    warning noise — the interpreter-shutdown contract, exercised live."""
    import gc
    import warnings

    pf = Prefetcher(lambda i: i, depth=1)
    assert next(pf) == 0
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        del pf
        gc.collect()


def test_prefetcher_runs_in_background_thread():
    tids = []

    def make(i):
        tids.append(threading.get_ident())
        return i

    with Prefetcher(make, depth=1, num_items=2) as pf:
        list(pf)
    assert tids and all(t != threading.get_ident() for t in tids)


def test_token_pipeline_close_then_next_raises():
    c = SyntheticCorpus(vocab=64, seq_len=8, num_shards=2)
    pipe = TokenPipeline(c, global_batch=4)
    next(pipe)
    pipe.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(pipe)


# --------------------------------------------------------------------------
# per-batch sampler RNG (the async-pipeline determinism contract)
# --------------------------------------------------------------------------


def _mag_sampler(seed=0, batch=8):
    from repro.core.metatree import build_metatree
    from repro.graph.sampler import NeighborSampler, SampleSpec
    from repro.graph.synthetic import ogbn_mag_like

    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    spec = SampleSpec.from_metatree(tree, [3, 2])
    return NeighborSampler(g, spec, batch, seed=seed)


def _assert_batches_equal(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(la.nids, lb.nids)
        np.testing.assert_array_equal(la.mask, lb.mask)


def test_batch_at_pure_function_of_position():
    """Same (seed, epoch, step) -> bit-identical batch, across restarts and
    out-of-order access."""
    s1, s2 = _mag_sampler(seed=5), _mag_sampler(seed=5)
    b_fwd = [s1.batch_at(i, epoch_seed=11) for i in range(3)]
    b_rev = [s2.batch_at(i, epoch_seed=11) for i in (2, 1, 0)][::-1]
    for x, y in zip(b_fwd, b_rev):
        _assert_batches_equal(x, y)
    # distinct positions / epochs actually differ
    assert not np.array_equal(b_fwd[0].levels[0].nids, b_fwd[1].levels[0].nids)
    assert not np.array_equal(
        b_fwd[0].levels[0].nids,
        _mag_sampler(seed=5).batch_at(0, epoch_seed=12).levels[0].nids,
    )


def test_epoch_iterator_matches_batch_at():
    s = _mag_sampler(seed=1)
    for i, b in zip(range(3), s.epoch(shuffle=True, seed=4)):
        _assert_batches_equal(b, s.batch_at(i, epoch_seed=4))


def test_adhoc_sample_batch_replays_across_instances():
    s1, s2 = _mag_sampler(seed=9), _mag_sampler(seed=9)
    seeds = s1.graph.train_nodes[:8]
    for _ in range(3):  # same call sequence -> same batches
        _assert_batches_equal(s1.sample_batch(seeds), s2.sample_batch(seeds))


# --------------------------------------------------------------------------
# SampleStream — background sample+stage
# --------------------------------------------------------------------------


def test_sample_stream_matches_serial():
    s = _mag_sampler(seed=2)
    staged = lambda b: int(b.seeds.sum())
    with SampleStream(lambda i: s.batch_at(i, epoch_seed=7), staged,
                      num_steps=4, depth=2) as stream:
        got = list(stream)
    assert len(got) == 4
    s2 = _mag_sampler(seed=2)
    for i, (batch, arrays, host_s) in enumerate(got):
        ref = s2.batch_at(i, epoch_seed=7)
        _assert_batches_equal(batch, ref)
        assert arrays == int(ref.seeds.sum())
        assert host_s >= 0.0


def test_sample_stream_defer_stage_runs_on_consumer():
    s = _mag_sampler(seed=2)
    stage_tids = []

    def staged(b):
        stage_tids.append(threading.get_ident())
        return 0

    with SampleStream(lambda i: s.batch_at(i, epoch_seed=7), staged,
                      num_steps=2, depth=2, defer_stage=True) as stream:
        list(stream)
    # "fresh" policy: staging happened on the consumer thread
    assert stage_tids and all(t == threading.get_ident() for t in stage_tids)


def test_sample_stream_shutdown_on_exception():
    def bad_stage(b):
        raise RuntimeError("stage failed")

    s = _mag_sampler(seed=2)
    stream = SampleStream(lambda i: s.batch_at(i, epoch_seed=7), bad_stage,
                          num_steps=4, depth=2)
    with pytest.raises(RuntimeError, match="stage failed"):
        list(stream)
    assert not stream._prefetcher._thread.is_alive()  # clean shutdown


# --------------------------------------------------------------------------
# pipeline parity across HGNN models (the relation-module IR runs every
# model on every executor — hgt × raf_spmd being the per-node-type case)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("model,executor", [
    ("rgat", "raf_spmd"),
    ("hgt", "raf_spmd"),
    ("hgt", "raf"),
])
def test_pipeline_parity_models(model, executor):
    """With frozen feature tables staging is time-invariant, so pipeline
    on/off must be bit-identical for every (model, executor) pair."""
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)

    def cfg(pipelined):
        c = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(model=model, hidden=32, train_learnable=False),
            run=RunConfig(executor=executor, steps=3, lr=1e-2, seed=0),
        )
        return c.updated(pipeline=dict(enabled=True)) if pipelined else c

    off = Heta(cfg(False)).run()
    on = Heta(cfg(True)).run()
    assert off["losses"] == on["losses"]  # bit-identical
    assert on["pipeline"] and not off["pipeline"]
    assert np.all(np.isfinite(on["losses"]))


def test_sample_stream_facade_validates_modes():
    s = _mag_sampler(seed=2)
    with pytest.raises(ValueError, match="worker_task"):
        SampleStream(lambda i: s.batch_at(i, epoch_seed=1), lambda b: b,
                     num_workers=2)
    with pytest.raises(ValueError, match="make_batch"):
        SampleStream(stage=lambda b: b, num_workers=0)
    with pytest.raises(ValueError, match="num_workers"):
        SampleStream(lambda i: i, lambda b: b, num_workers=-1)


# --------------------------------------------------------------------------
# multi-worker host pipeline (process pool over the shm graph store):
# workers ∈ {0, 1, 4} must be bit-identical to the serial loop on every
# executor — frozen tables make staging time-invariant, and batch_at purity
# makes the stripe decomposition invisible (DESIGN.md §9)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("executor", ["vanilla", "raf", "raf_spmd"])
def test_worker_pool_parity_all_executors(executor):
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)
    from repro.graph.shm import live_segments

    def run(workers):
        c = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(hidden=32, train_learnable=False),
            run=RunConfig(executor=executor, steps=3, lr=1e-2, seed=0),
        )
        if workers is not None:
            c = c.updated(pipeline=dict(enabled=True, num_workers=workers))
        sess = Heta(c)
        try:
            return sess.run()
        finally:
            sess.close_pipeline()

    serial = run(None)
    for w in (0, 1, 4):
        r = run(w)
        assert serial["losses"] == r["losses"], (executor, w)
        assert r["sampler_workers"] == w
        assert r["samples_per_s"] > 0
        if w:  # arena mode: queue carries SlotRef descriptors, not arrays
            assert 0 < r["queue_bytes_per_step"] < 1024, (executor, w)
    assert serial["sampler_workers"] == 0
    assert not live_segments()  # every run released its store


def test_worker_pool_legacy_pickle_path_still_bit_identical():
    """``pipeline.arena=False`` keeps the PR-5 pickle transport: same
    batches, same losses, megabyte queue items (the cost the arena
    removes)."""
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)
    from repro.graph.shm import live_segments

    def run(arena):
        c = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(hidden=32, train_learnable=False),
            run=RunConfig(executor="raf_spmd", steps=3, lr=1e-2, seed=0),
        ).updated(pipeline=dict(enabled=True, num_workers=2, arena=arena))
        sess = Heta(c)
        try:
            return sess.run()
        finally:
            sess.close_pipeline()

    on, off = run(True), run(False)
    assert on["losses"] == off["losses"]
    assert on["queue_bytes_per_step"] < 1024 < off["queue_bytes_per_step"]
    assert not live_segments()


def _learnable_run(workers, snapshot="stale", arena=True):
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)

    c = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                        batch_size=16),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(hidden=32, train_learnable=True),
        run=RunConfig(executor="raf_spmd", steps=3, lr=1e-2, seed=0),
    )
    if workers is not None:
        c = c.updated(pipeline=dict(enabled=True, num_workers=workers,
                                    snapshot=snapshot, arena=arena))
    sess = Heta(c)
    try:
        return sess.run()
    finally:
        sess.close_pipeline()


def test_worker_pool_learnable_fresh_is_bit_exact():
    """Under the "fresh" snapshot policy pool workers only sample — staging
    runs consumer-side against the just-updated tables, so pooled losses
    are bit-exact at every worker count."""
    serial = _learnable_run(None)
    for w in (0, 1, 4):
        assert serial["losses"] == _learnable_run(w, snapshot="fresh")["losses"], w


def test_worker_pool_learnable_stale_stages_in_workers_bounded():
    """Under the default "stale" policy with the batch arena, workers stage
    against seqlock-republished table snapshots at most the ring depth
    behind the trainer (DESIGN.md §11): the loss trajectory tracks the
    serial path within optimization noise, and the queue stays zero-pickle
    (SlotRef descriptors only)."""
    serial = _learnable_run(None)
    stale = _learnable_run(2, snapshot="stale")
    assert np.allclose(serial["losses"], stale["losses"], atol=5e-2)
    assert 0 < stale["queue_bytes_per_step"] < 1024  # descriptors, not arrays


def test_pool_persists_across_fits_and_stays_bit_identical():
    """Consecutive fit() calls reuse one pool + shm store (spawn amortized)
    and the two-fit pooled trajectory equals one serial fit of the same
    length; a serial step() in between forces a clean respawn."""
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)
    from repro.graph.shm import live_segments

    def cfg(workers=None):
        c = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(hidden=32, train_learnable=False),
            run=RunConfig(executor="raf_spmd", steps=6, lr=1e-2, seed=0),
        )
        if workers is not None:
            c = c.updated(pipeline=dict(enabled=True, num_workers=workers))
        return c

    serial = Heta(cfg()).run()

    sess = Heta(cfg(workers=2))
    sess.build_graph(); sess.partition(); sess.profile_and_cache(); sess.compile()
    sess.fit(2)
    pool_a = sess._pool_cache[2]
    sess.fit(2)
    assert sess._pool_cache[2] is pool_a  # reused, not respawned
    sess.step()  # serial step desyncs the stripe position...
    sess.fit(1)
    assert sess._pool_cache[2] is not pool_a  # ...so the pool respawned
    assert sess.losses == serial["losses"]
    sess.close_pipeline()
    assert sess._pool_cache is None
    sess.close_pipeline()  # idempotent
    assert not live_segments()


def test_evaluate_with_workers_matches_serial():
    from repro.api import (DataConfig, Heta, HetaConfig, ModelConfig,
                           PartitionConfig, RunConfig)

    def build(workers):
        c = HetaConfig(
            data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(3, 2),
                            batch_size=16),
            partition=PartitionConfig(num_partitions=2),
            model=ModelConfig(hidden=32, train_learnable=False),
            run=RunConfig(executor="raf_spmd", steps=0, lr=1e-2, seed=0),
        )
        if workers is not None:
            c = c.updated(pipeline=dict(enabled=True, num_workers=workers))
        sess = Heta(c)
        sess.run()
        return sess

    ref = build(None).evaluate(num_batches=2)
    pooled = build(2).evaluate(num_batches=2)
    assert ref["loss"] == pooled["loss"]
    assert ref["num_batches"] == pooled["num_batches"] == 2


def test_seedless_epochs_vary_but_replay_deterministically():
    """epoch() without a seed draws fresh samples each call (multi-epoch
    training loops keep sampling variance), yet a fresh sampler replays the
    same sequence of epochs."""
    s1, s2 = _mag_sampler(seed=3), _mag_sampler(seed=3)
    e1a, e1b = next(s1.epoch()), next(s1.epoch())
    assert not np.array_equal(e1a.levels[0].nids, e1b.levels[0].nids)
    _assert_batches_equal(e1a, next(s2.epoch()))
    _assert_batches_equal(e1b, next(s2.epoch()))
