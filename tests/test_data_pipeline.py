"""Token pipeline: determinism, sharding arithmetic, prefetch liveness."""

import numpy as np

from repro.data import SyntheticCorpus, TokenPipeline


def test_corpus_deterministic_and_shifted():
    c = SyntheticCorpus(vocab=1000, seq_len=32, num_shards=4, seed=7)
    a = c.sequence(1, 5)
    b = c.sequence(1, 5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(c.sequence(1, 6), a)
    batch = c.batch(0, 0, 4)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])
    assert batch["tokens"].max() < 1000


def test_pipeline_shapes_and_progress():
    c = SyntheticCorpus(vocab=512, seq_len=16, num_shards=4)
    pipe = TokenPipeline(c, global_batch=8, prefetch=2)
    try:
        b1 = next(pipe)
        b2 = next(pipe)
        assert b1["tokens"].shape == (8, 16)
        assert b1["labels"].shape == (8, 16)
        assert not np.array_equal(b1["tokens"], b2["tokens"])
    finally:
        pipe.close()


def test_pipeline_multi_host_split():
    c = SyntheticCorpus(vocab=512, seq_len=16, num_shards=4)
    p0 = TokenPipeline(c, global_batch=8, host_id=0, num_hosts=2)
    p1 = TokenPipeline(c, global_batch=8, host_id=1, num_hosts=2)
    try:
        b0, b1 = next(p0), next(p1)
        assert b0["tokens"].shape == (4, 16)  # half the global batch each
        assert not np.array_equal(b0["tokens"], b1["tokens"])  # disjoint shards
    finally:
        p0.close()
        p1.close()


def test_pipeline_feeds_training():
    import jax

    import repro.configs.all_archs  # noqa: F401
    from repro.configs.base import ARCHS
    from repro.models import init_train_state, make_train_step

    cfg = ARCHS["qwen2-1.5b"].reduced()
    c = SyntheticCorpus(vocab=cfg.vocab, seq_len=32, num_shards=2)
    pipe = TokenPipeline(c, global_batch=2)
    try:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, donate=False)
        import jax.numpy as jnp

        for _ in range(2):
            b = next(pipe)
            state, loss = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            assert np.isfinite(float(loss))
    finally:
        pipe.close()
