"""Batch arena (DESIGN.md §11): slot ring protocol, seqlock'd staging
tables, pack/unpack round trips, zero-pickle descriptors through a real
worker pool, and crash/lifecycle hygiene."""

import dataclasses
import os
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core.metatree import build_metatree
from repro.data.staging import (
    BATCH_PREFIX,
    HOST_PREFIX,
    arena_fields,
    pack_batch_arrays,
    pack_batch_into,
    unpack_slot,
)
from repro.data.worker_pool import (
    EpochSchedule,
    SampleStageTask,
    SlotRef,
    WorkerDiedError,
    WorkerPool,
)
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.shm import (
    attach_arena,
    create_arena,
    live_segments,
    share_graph,
)
from repro.graph.synthetic import ogbn_mag_like

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="arena tests rely on /dev/shm"
)


def _mag():
    g = ogbn_mag_like(scale=0.002)
    tree = build_metatree(g.metagraph(), g.target_type, 2)
    return g, SampleSpec.from_metatree(tree, [3, 2])


def _probe_fields():
    return {"x": np.zeros((4, 3), np.float32), "y": np.zeros(4, np.int64)}


def _assert_batches_equal(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(a.labels, b.labels)
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(la.nids, lb.nids)
        np.testing.assert_array_equal(la.mask, lb.mask)


# --------------------------------------------------------------------------
# slot ring protocol
# --------------------------------------------------------------------------


def test_slot_for_is_per_worker_sub_ring():
    with create_arena(_probe_fields(), num_workers=2, depth=2) as a:
        h = a.handle
        assert h.n_slots == 4
        # stripe item i -> worker i % 2; each worker cycles its own 2 slots
        assert [h.slot_for(i) for i in range(8)] == [
            (0, 0), (2, 0), (1, 0), (3, 0), (0, 1), (2, 1), (1, 1), (3, 1)]


def test_wraparound_reuse_and_stale_generation_rejected():
    """A slot is reused across generations; resolving the wrong generation
    (a descriptor outliving its slot) raises instead of returning torn
    data."""
    with create_arena(_probe_fields(), num_workers=1, depth=1) as a:
        for use in range(3):
            assert a.wait_writable(0, use, timeout=1.0)
            a.begin_write(0, use)
            a.slot_views(0, writable=True)["x"][:] = float(use)
            a.end_write(0, use)
            views = a.resolve(0, use)
            assert float(views["x"][0, 0]) == float(use)
            a.release(0, use)
        with pytest.raises(RuntimeError, match="generation"):
            a.resolve(0, 0)  # stale descriptor after two overwrites
        a.begin_write(0, 3)
        with pytest.raises(RuntimeError, match="write_seq"):
            a.resolve(0, 3)  # mid-write (odd seq) is a protocol violation


def test_backpressure_blocks_until_release():
    """With every generation of a slot in flight the writer's gate stays
    shut (timeout) and opens as soon as the consumer releases."""
    with create_arena(_probe_fields(), num_workers=1, depth=1) as a:
        a.begin_write(0, 0)
        a.end_write(0, 0)
        # generation 1 must wait: generation 0 not yet consumed
        t0 = time.perf_counter()
        assert not a.wait_writable(0, 1, timeout=0.05)
        assert time.perf_counter() - t0 >= 0.05

        stop = threading.Event()
        assert not a.wait_writable(0, 1, stop=stop, timeout=0.05)

        def _release():
            time.sleep(0.02)
            a.release(0, 0)

        t = threading.Thread(target=_release)
        t.start()
        assert a.wait_writable(0, 1, timeout=2.0)
        t.join()


def test_stop_event_exits_backpressure_wait():
    with create_arena(_probe_fields(), num_workers=1, depth=1) as a:
        a.begin_write(0, 0)
        a.end_write(0, 0)
        stop = threading.Event()

        def _trip():
            time.sleep(0.02)
            stop.set()

        t = threading.Thread(target=_trip)
        t.start()
        t0 = time.perf_counter()
        assert not a.wait_writable(0, 1, stop=stop, timeout=5.0)
        assert time.perf_counter() - t0 < 4.0  # exited on stop, not timeout
        t.join()


# --------------------------------------------------------------------------
# seqlock'd staging tables
# --------------------------------------------------------------------------


def test_immutable_tables_are_zero_copy_views():
    tab = np.arange(12, dtype=np.float32).reshape(3, 4)
    with create_arena(_probe_fields(), num_workers=1, depth=1,
                      tables={"paper": tab}) as a:
        views, ver = a.read_tables()
        assert ver == 0
        np.testing.assert_array_equal(views["paper"], tab)
        assert not views["paper"].flags.writeable  # view, not copy
        with pytest.raises(RuntimeError, match="immutable"):
            a.publish_tables({"paper": tab})


def test_publish_bumps_version_and_readers_see_whole_updates():
    tab = np.zeros((64, 16), np.float32)
    with create_arena(_probe_fields(), num_workers=1, depth=1,
                      tables={"t": tab}, tables_mutable=True) as a:
        a.publish_tables({"t": np.full_like(tab, 7.0)})
        out, ver = a.read_tables()
        assert ver == 2 and np.all(out["t"] == 7.0)
        assert out["t"].flags.writeable  # mutable path returns a copy


def test_seqlock_retries_torn_reads_under_concurrent_writer():
    """A writer thread republishes uniform-valued tables as fast as it can;
    every read must observe one publish in full — a mixed-value table is a
    torn read the seqlock failed to retry."""
    tab = np.zeros((256, 32), np.float32)
    with create_arena(_probe_fields(), num_workers=1, depth=1,
                      tables={"t": tab}, tables_mutable=True) as a:
        stop = threading.Event()

        def _writer():
            v = 0.0
            while not stop.is_set():
                v += 1.0
                a.publish_tables({"t": np.full_like(tab, v)})

        w = threading.Thread(target=_writer)
        w.start()
        try:
            deadline = time.monotonic() + 1.0
            reads = 0
            while time.monotonic() < deadline:
                out, ver = a.read_tables()
                assert ver % 2 == 0  # never returns mid-publish
                vals = np.unique(out["t"])
                assert len(vals) == 1, f"torn read: {vals}"
                reads += 1
            assert reads > 0
        finally:
            stop.set()
            w.join()


# --------------------------------------------------------------------------
# pack / unpack round trip (staging helpers)
# --------------------------------------------------------------------------


def test_pack_unpack_round_trip_is_bit_identical():
    g, spec = _mag()
    s = NeighborSampler(g, spec, 8, seed=5)
    batch = s.batch_at(0, epoch_seed=3)
    fields = arena_fields(batch)
    assert all(k.startswith(BATCH_PREFIX) for k in fields)
    with create_arena(fields, num_workers=1, depth=1) as a:
        a.begin_write(0, 0)
        pack_batch_into(a.slot_views(0, writable=True), batch)
        a.end_write(0, 0)
        got, host = unpack_slot(a.resolve(0, 0), spec)
        assert host is None
        _assert_batches_equal(got, batch)
        # flat reference: same arrays as the pure-dict pack
        flat = pack_batch_arrays(batch)
        views = a.resolve(0, 0)
        for k in flat:
            np.testing.assert_array_equal(views[k], flat[k])


def test_arena_fields_includes_host_arrays_with_recipe():
    pytest.importorskip("jax")
    from repro.core.hgnn import HGNNConfig
    from repro.core.meta_partition import meta_partition
    from repro.core.raf import assign_branches
    from repro.core import raf_spmd
    from repro.data.staging import stack_batch_host

    g, _ = _mag()
    mp_ = meta_partition(g, 2, num_layers=2)
    spec = SampleSpec.from_metatree(mp_.metatree, [3, 2])
    cfg = HGNNConfig(model="rgcn", hidden=32, num_layers=2, num_heads=4,
                     num_classes=g.num_classes, learnable_dim=16)
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    plan = raf_spmd.build_plan(spec, assign_branches(spec, mp_), cfg,
                               feat_dims)
    recipe = raf_spmd.stack_recipe(plan)
    rng = np.random.default_rng(0)
    tables = {
        t: (g.features[t].astype(np.float32) if t in g.features
            else rng.standard_normal((g.num_nodes[t], 16)).astype(np.float32))
        for t in g.num_nodes
    }
    s = NeighborSampler(g, spec, 8, seed=5)
    batch = s.batch_at(0, epoch_seed=3)
    fields = arena_fields(batch, recipe=recipe, tables=tables)
    assert any(k.startswith(HOST_PREFIX) for k in fields)
    with create_arena(fields, num_workers=1, depth=1) as a:
        views = a.slot_views(0, writable=True)
        pack_batch_into(views, batch)
        stack_batch_host(recipe, batch, tables, out=views,
                         prefix=HOST_PREFIX)
        got, host = unpack_slot(a.slot_views(0), spec)
        _assert_batches_equal(got, batch)
        ref = stack_batch_host(recipe, batch, tables)
        assert set(host) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(host[k], ref[k])


# --------------------------------------------------------------------------
# through a real worker pool
# --------------------------------------------------------------------------


def test_pool_arena_descriptors_stay_tiny_and_batches_match_serial():
    """The zero-pickle guarantee: with the arena the queue carries SlotRef
    descriptors under 1 KiB, and the resolved batches are bit-identical to
    the serial sampler."""
    g, spec = _mag()
    serial = NeighborSampler(g, spec, 8, seed=5)
    E = serial.steps_per_epoch()
    store = share_graph(g, include_features=False)
    batch0 = serial.batch_at(0, epoch_seed=77)
    arena = create_arena(arena_fields(batch0), num_workers=2, depth=2)
    try:
        task = SampleStageTask(
            handle=store.handle, spec=spec, batch_size=8, sampler_seed=5,
            schedule=EpochSchedule(77, E), arena=arena.handle,
        )
        n = min(E + 2, 8)  # cross a wrap-around of each sub-ring
        with WorkerPool(task, num_workers=2, depth=2, num_items=n) as pool:
            for i, ref in enumerate(pool):
                assert isinstance(ref, SlotRef)
                assert len(pickle.dumps(ref)) < 1024
                assert (ref.slot, ref.use) == arena.handle.slot_for(i)
                batch, host = unpack_slot(arena.resolve(ref.slot, ref.use),
                                          spec)
                assert host is None
                seed, idx = EpochSchedule(77, E).seed_and_index(i)
                _assert_batches_equal(
                    batch, serial.batch_at(idx, epoch_seed=seed))
                arena.release(ref.slot, ref.use)
    finally:
        store.unlink()
        arena.unlink()
    assert not live_segments()


@dataclasses.dataclass
class CrashAfterWriteTask:
    """Writes one slot, then dies hard mid-stripe — the leak test below
    checks the parent can still unlink every segment."""

    arena: object
    crash_at: int = 1

    def setup(self):
        self._a = attach_arena(self.arena)

    def bind_stop(self, stop):
        self._stop = stop

    def __call__(self, i):
        if i == self.crash_at:
            os._exit(13)  # hard crash: no teardown, no atexit
        slot, use = self._a.handle.slot_for(i)
        if not self._a.wait_writable(slot, use, stop=self._stop, timeout=30):
            return None
        self._a.begin_write(slot, use)
        self._a.slot_views(slot, writable=True)["x"][:] = float(i)
        self._a.end_write(slot, use)
        return SlotRef(step=i, slot=slot, use=use, host_s=0.0)

    def teardown(self):
        self._a.close()


def test_worker_crash_surfaces_and_leaks_no_segments():
    arena = create_arena(_probe_fields(), num_workers=1, depth=2)
    try:
        task = CrashAfterWriteTask(arena=arena.handle)
        pool = WorkerPool(task, num_workers=1, depth=2, num_items=4)
        got = []
        with pytest.raises(WorkerDiedError, match="exited"):
            for ref in pool:
                got.append(ref)
                arena.release(ref.slot, ref.use)
        # item 0 may or may not flush through the queue's feeder thread
        # before os._exit kills it; whatever arrives is in stripe order
        assert [r.step for r in got] in ([], [0])
        assert all(not p.is_alive() for p in pool._procs)
    finally:
        arena.unlink()
    assert not live_segments()  # owner-side unlink survives worker death


def test_create_arena_validates_and_is_transactional():
    with pytest.raises(ValueError, match="num_workers"):
        create_arena(_probe_fields(), num_workers=0, depth=2)
    with pytest.raises(ValueError, match="num_workers"):
        create_arena(_probe_fields(), num_workers=1, depth=0)
    # a bad table must not leak the segment
    with pytest.raises(AttributeError):
        create_arena(_probe_fields(), num_workers=1, depth=1,
                     tables={"t": object()})
    assert not live_segments()
