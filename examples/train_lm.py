"""Train an assigned-architecture LM (reduced variant) on the token pipeline.

Demonstrates the full LM training path: synthetic sharded corpus →
prefetching pipeline → period-structured transformer → AdamW, with loss
falling over a few hundred steps.  The full-size configs train identically
on the production mesh (lowering proven by the dry-run); this example keeps
CPU wall-clock sane with the reduced config.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-moe-30b-a3b --steps 100
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs.all_archs  # noqa: F401
from repro.configs.base import ARCHS
from repro.data import SyntheticCorpus, TokenPipeline
from repro.models import init_train_state, make_train_step
from repro.optim.adam import AdamConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    if cfg.frontend:
        raise SystemExit("pick a text decoder arch for this example")
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"L={cfg.num_layers} d={cfg.d_model}")

    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=args.seq_len, num_shards=8)
    pipe = TokenPipeline(corpus, global_batch=args.batch, prefetch=2)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, AdamConfig(lr=1e-3, grad_clip=1.0))

    losses = []
    t0 = time.time()
    try:
        for i in range(args.steps):
            b = next(pipe)
            state, loss = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(loss))
            if i % 10 == 0:
                print(f"step {i:4d}  loss {losses[-1]:.4f}")
    finally:
        pipe.close()
    k = max(1, len(losses) // 10)
    print(f"\nloss {np.mean(losses[:k]):.4f} -> {np.mean(losses[-k:]):.4f} "
          f"in {time.time()-t0:.0f}s "
          f"({'improving' if np.mean(losses[-k:]) < np.mean(losses[:k]) else 'flat'})")


if __name__ == "__main__":
    main()
