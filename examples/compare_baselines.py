"""Baseline comparison (paper Fig. 8/9 in miniature): Heta vs the two
ablation baselines the paper isolates —

  * ``vanilla``-style: naive relation placement (inner-level partials cross
    the network, the DGL-like regime) + no cache;
  * ``hotness-only`` cache (GNNLab/GraphLearn-style allocation);
  * full Heta: meta-partitioning + miss-penalty cache.

All three are one HetaConfig apart — placement / cache policy are config
strings, the executor protocol is shared, and ``--model`` swaps the HGNN
relation module (rgcn/rgat/hgt) without touching anything else.  Prints
measured step time and cache hit rates.

Run:  PYTHONPATH=src python examples/compare_baselines.py [--model hgt]
"""

import argparse

from repro.api import Heta, HetaConfig, DataConfig, ModelConfig, PartitionConfig, RunConfig


def configs(model: str, steps: int):
    base = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.005, fanouts=(10, 10),
                        batch_size=64),
        partition=PartitionConfig(num_partitions=2),
        model=ModelConfig(model=model),
        run=RunConfig(executor="raf_spmd", steps=steps),
    )
    return [
        ("vanilla-like", base.updated(partition=dict(placement="naive"),
                                      cache=dict(cache_mb=0))),
        ("hotness-cache", base.updated(cache=dict(cache_mb=8, policy="hotness"))),
        ("heta", base.updated(cache=dict(cache_mb=8))),
    ]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="rgcn", choices=("rgcn", "rgat", "hgt"),
                    help="HGNN relation module to train")
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args(argv)
    print(f"model={args.model}")
    print(f"{'config':<16} {'step ms':>9} {'meta-local':>10}  hit rates")
    for name, cfg in configs(args.model, args.steps):
        m = Heta(cfg).run()
        hits = {t: round(r, 2) for t, r in m["hit_rates"].items()}
        print(f"{name:<16} {m['step_time_s']*1e3:9.1f} "
              f"{str(m['meta_local']):>10}  {hits}")


if __name__ == "__main__":
    main()
