"""Baseline comparison (paper Fig. 8/9 in miniature): Heta vs the two
ablation baselines the paper isolates —

  * ``vanilla``-style: naive relation placement (inner-level partials cross
    the network, the DGL-like regime) + no cache;
  * ``hotness-only`` cache (GNNLab/GraphLearn-style allocation);
  * full Heta: meta-partitioning + miss-penalty cache.

Prints measured step time, exact per-batch comm bytes and cache hit rates.

Run:  PYTHONPATH=src python examples/compare_baselines.py
"""

import numpy as np

from repro.launch.train import train_hgnn

CONFIGS = [
    ("vanilla-like", dict(naive_placement=True, cache_mb=0)),
    ("hotness-cache", dict(hotness_only=True)),
    ("heta", dict()),
]


def main():
    print(f"{'config':<16} {'step ms':>9} {'meta-local':>10}  hit rates")
    for name, kw in CONFIGS:
        m = train_hgnn(
            dataset="ogbn-mag", scale=0.005, model="rgcn", num_partitions=2,
            batch_size=64, fanouts=(10, 10), steps=6, cache_mb=kw.pop("cache_mb", 8),
            **kw,
        )
        hits = {t: round(r, 2) for t, r in m["hit_rates"].items()}
        print(f"{name:<16} {m['step_time_s']*1e3:9.1f} "
              f"{str(m['meta_local']):>10}  {hits}")


if __name__ == "__main__":
    main()
