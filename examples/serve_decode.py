"""Serve a small LM with batched requests: prefill then token-by-token decode.

Uses the reduced llama3.2-3b config (the full configs are exercised by the
512-device dry-run); demonstrates the prefill→decode cache handoff and the
sliding-window ring-buffer mode used by the long_500k shape.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch llama3.2-3b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs.all_archs  # noqa: F401
from repro.configs.base import ARCHS
from repro.models import (
    init_params,
    make_prefill_step,
    make_serve_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    if not cfg.is_decoder:
        raise SystemExit(f"{args.arch} is encoder-only; pick a decoder arch")
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len

    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    prefill = make_prefill_step(cfg)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    print(f"prefill: {B} requests × {S} tokens in {(time.time()-t0)*1e3:.0f} ms")

    # grow the cache to hold the generated continuation
    total = S + args.new_tokens
    if "k" in cache:
        pad = [(0, 0)] * 6
        pad[3] = (0, args.new_tokens)
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)

    serve = make_serve_step(cfg, donate=False)
    token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [token]
    t0 = time.time()
    for pos in range(S, total):
        logits, cache = serve(params, cache, token, jnp.asarray(pos, jnp.int32))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(token)
    jax.block_until_ready(token)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.new_tokens} tokens × {B} requests in {dt*1e3:.0f} ms "
          f"({dt / args.new_tokens * 1e3:.1f} ms/token)")
    print("sampled continuations (token ids):")
    for b in range(B):
        print(f"  req{b}: {np.asarray(out[b])[:12]} ...")


if __name__ == "__main__":
    main()
