"""Quickstart: the Heta pipeline end-to-end on a laptop-sized HetG,
stage by stage through the :class:`repro.api.Heta` session.

Builds an ogbn-mag-like heterogeneous graph, meta-partitions it (paper §5),
shows the metatree and the communication-volume comparison against the
vanilla execution model (§4), allocates the miss-penalty cache (§6), then
trains a 2-layer R-GCN with the SPMD RAF executor.

Run:  PYTHONPATH=src python examples/quickstart.py
(or after `pip install -e .`:  python examples/quickstart.py)
"""

from repro.api import CacheConfig, DataConfig, Heta, HetaConfig, PartitionConfig, RunConfig


def main():
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.01, fanouts=(10, 10),
                        batch_size=64),
        partition=PartitionConfig(num_partitions=2),
        cache=CacheConfig(cache_mb=8),
        run=RunConfig(executor="raf_spmd", steps=10, log_every=2),
    )
    sess = Heta(cfg)

    # --- stage 1: the graph ------------------------------------------------
    g = sess.build_graph()
    print(f"graph: {g.name}  nodes={g.total_nodes:,}  edges={g.total_edges:,}")
    print(f"node types: {g.node_types}  target: {g.target_type!r}\n")

    # --- stage 2: §5 meta-partitioning --------------------------------------
    part = sess.partition()
    print("metatree (HGNN computation dependency):")
    print(part.metatree.render())
    print()
    print(part.summary, "\n")

    # --- §4 communication comparison (inspectable before training) ----------
    comm = sess.comm_report(bytes_per_elem=2)
    vanilla = comm["vanilla_feat"]
    heta = comm["raf_meta"]
    print(f"per-batch communication (batch={cfg.data.batch_size}, "
          f"fanout {'x'.join(map(str, cfg.data.fanouts))}, fp16):")
    print(f"  vanilla feature fetching : {vanilla/1e6:8.2f} MB")
    print(f"  RAF, naive placement     : {comm['raf_naive']/1e6:8.2f} MB")
    print(f"  Heta RAF + meta-partition: {heta/1e6:8.2f} MB"
          f"   ({vanilla/max(heta,1):.0f}x less)\n")

    # --- stage 3: §6 cache ---------------------------------------------------
    cache = sess.profile_and_cache()
    print(f"cache rows per type: {cache.allocation_rows}\n")

    # --- stages 4+5: compile + train ----------------------------------------
    print(f"training R-GCN with the {cfg.run.executor!r} executor "
          f"({cfg.run.steps} steps)...")
    m = sess.compile().fit()
    print(f"\ncache hit rates: "
          f"{ {k: round(v, 2) for k, v in m['hit_rates'].items()} }")
    print(f"median step time: {m['step_time_s']*1e3:.1f} ms")


if __name__ == "__main__":
    main()
