"""Quickstart: the Heta pipeline end-to-end on a laptop-sized HetG.

Builds an ogbn-mag-like heterogeneous graph, meta-partitions it (paper §5),
shows the metatree and the communication-volume comparison against the
vanilla execution model (§4), then trains a 2-layer R-GCN with the RAF
executor and the miss-penalty cache (§6).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.comm import vanilla_comm_bytes
from repro.core.meta_partition import meta_partition, random_edge_cut
from repro.core.raf import assign_branches, raf_comm_bytes
from repro.graph.sampler import NeighborSampler, SampleSpec
from repro.graph.synthetic import ogbn_mag_like
from repro.launch.train import train_hgnn


def main():
    g = ogbn_mag_like(scale=0.01)
    print(f"graph: {g.name}  nodes={g.total_nodes:,}  edges={g.total_edges:,}")
    print(f"node types: {g.node_types}  target: {g.target_type!r}\n")

    # --- §5 meta-partitioning --------------------------------------------
    mp = meta_partition(g, num_partitions=2, num_layers=2)
    print("metatree (HGNN computation dependency):")
    print(mp.metatree.render())
    print()
    print(mp.summary(), "\n")

    # --- §4 communication comparison --------------------------------------
    spec = SampleSpec.from_metatree(mp.metatree, (25, 20))
    batch = NeighborSampler(g, spec, 1024, seed=0).sample_batch(
        g.train_nodes[:1024]
    )
    feat_dims = {t: g.feat_dim(t) for t in g.num_nodes if g.feat_dim(t)}
    vanilla = vanilla_comm_bytes(batch, random_edge_cut(g, 2), feat_dims,
                                 bytes_per_elem=2)
    heta = raf_comm_bytes(spec, assign_branches(spec, mp), 1024, 64, 2)
    print(f"per-batch communication (batch=1024, fanout 25x20, fp16):")
    print(f"  vanilla feature fetching : {vanilla/1e6:8.2f} MB")
    print(f"  Heta RAF + meta-partition: {heta/1e6:8.2f} MB"
          f"   ({vanilla/max(heta,1):.0f}x less)\n")

    # --- train -------------------------------------------------------------
    print("training R-GCN with the RAF executor (10 steps)...")
    m = train_hgnn(dataset="ogbn-mag", scale=0.01, model="rgcn",
                   num_partitions=2, batch_size=64, fanouts=(10, 10),
                   steps=10, cache_mb=8, log_every=2)
    print(f"\ncache hit rates: "
          f"{ {k: round(v, 2) for k, v in m['hit_rates'].items()} }")
    print(f"median step time: {m['step_time_s']*1e3:.1f} ms")


if __name__ == "__main__":
    main()
