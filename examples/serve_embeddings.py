"""Quickstart for the online inference tier (``repro.serve``, DESIGN.md §10):
train a small HGNN, materialize every node's embedding with layer-wise
full-graph inference, then answer lookups through the micro-batching
embedding server.

Run:  PYTHONPATH=src python examples/serve_embeddings.py
"""

import threading

import numpy as np

from repro.api import DataConfig, Heta, HetaConfig, ModelConfig, RunConfig, ServeConfig
from repro.serve import bounded_graph


def main():
    cfg = HetaConfig(
        data=DataConfig(dataset="ogbn-mag", scale=0.002, fanouts=(4, 4),
                        batch_size=16),
        model=ModelConfig(model="rgcn", hidden=32, num_heads=2,
                          learnable_dim=16),
        run=RunConfig(executor="raf_spmd", steps=5),
        serve=ServeConfig(max_batch=16, max_wait_ms=2.0),
    )
    sess = Heta(cfg)

    # --- train (cap in-degree so full-graph inference stays laptop-sized) ---
    g = bounded_graph(sess.build_graph(), 8)
    sess.build_graph(g)
    sess.partition()
    sess.profile_and_cache()
    sess.compile()
    sess.fit()
    print(f"trained: loss {sess.losses[-1]:.4f}\n")

    # --- materialize every node's embedding once ----------------------------
    store = sess.infer_all()
    for t, emb in sorted(store.embeddings.items()):
        print(f"  embeddings[{t!r}]: {emb.shape} (layer {store.layer_of[t]})")
    print(f"  store: {store.nbytes / 2**20:.2f} MiB\n")

    # --- serve: concurrent lookups coalesce into micro-batches --------------
    server = sess.serve()
    n = g.num_nodes[g.target_type]

    def client(k: int) -> None:
        rng = np.random.default_rng(k)
        for _ in range(16):
            res = server.query(rng.integers(0, n, 4))
            assert res.scores.shape == (4, g.num_classes)

    threads = [threading.Thread(target=client, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    print("server stats after 64 concurrent lookups:")
    print(server.stats().render())

    # --- full-graph evaluation against the materialized store ---------------
    ev = sess.evaluate(num_batches=2, use_full_graph=True)
    print(f"\nfull-graph eval loss: {ev['loss']:.4f}")
    sess.close_serving()


if __name__ == "__main__":
    main()
