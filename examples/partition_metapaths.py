"""User-defined metapaths (paper §5 / §7: the Partition API's optional
``metapaths`` argument).

Instead of the k-depth BFS metatree, the user supplies semantic metapaths —
here ogbn-mag's classic P-A-P ("papers by shared authors") and P-F-P
("papers sharing a field of study") — and meta-partitioning builds the
metatree from exactly those aggregation paths.  Branch counts, partitions
and the communication bound follow the supplied paths rather than the full
schema.

Run:  PYTHONPATH=src python examples/partition_metapaths.py
"""

from repro.core.meta_partition import meta_partition
from repro.core.raf import assign_branches, raf_comm_bytes
from repro.graph.hetgraph import Relation
from repro.graph.sampler import SampleSpec
from repro.graph.synthetic import ogbn_mag_like


def main():
    g = ogbn_mag_like(scale=0.01)
    # metapaths are walked from the target type via in-relations:
    #   P <-writes- A <-rev_writes- P        (shared authors)
    #   P <-rev_has_topic- F <-has_topic- P  (shared fields)
    pap = [
        Relation("author", "writes", "paper"),
        Relation("paper", "rev_writes", "author"),
    ]
    pfp = [
        Relation("field_of_study", "rev_has_topic", "paper"),
        Relation("paper", "has_topic", "field_of_study"),
    ]

    for name, metapaths in (("BFS (full schema)", None),
                            ("P-A-P + P-F-P metapaths", [pap, pfp])):
        mp = meta_partition(g, 2, num_layers=2, metapaths=metapaths)
        spec = SampleSpec.from_metatree(mp.metatree, (25, 20))
        comm = raf_comm_bytes(spec, assign_branches(spec, mp), 1024, 64, 2)
        n_branches = sum(len(l) for l in spec.levels)
        print(f"== {name}")
        print(mp.metatree.render())
        print(f"   branches={n_branches}  partitions:"
              f" {[len(p.relations) for p in mp.partitions]} relations"
              f"  per-batch comm={comm/1e6:.2f} MB\n")


if __name__ == "__main__":
    main()
