"""End-to-end driver: train a ~100M-parameter HGNN for a few hundred steps.

The model is R-GAT over a Freebase-like HetG where every node type is
featureless — the ~100M parameters are dominated by the learnable feature
tables (≈1.5M nodes × 64 dims) plus per-relation attention weights, exactly
the regime Heta's cache targets (paper §2.3: learnable-feature updates are
24-35% of DGL's epoch time).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
      (add --pipeline for the async host pipeline, --num-workers N to feed
      the device from N sampler processes over the shared-memory graph
      store — DESIGN.md §9)
"""

import argparse
import time

import numpy as np

from repro.api import (
    CacheConfig, DataConfig, Heta, HetaConfig, ModelConfig, PartitionConfig,
    RunConfig,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--pipeline", action="store_true",
                    help="overlap host sampling+staging with the device step")
    ap.add_argument("--num-workers", type=int, default=0,
                    help="sampler worker processes (0 = one thread)")
    args = ap.parse_args()

    cfg = HetaConfig(
        data=DataConfig(dataset="freebase", scale=0.001, fanouts=(10, 5),
                        batch_size=args.batch_size),
        partition=PartitionConfig(num_partitions=4),
        model=ModelConfig(model="rgat", hidden=64),
        cache=CacheConfig(cache_mb=32),
        run=RunConfig(executor="raf_spmd", steps=args.steps, log_every=10),
    )
    if args.pipeline or args.num_workers:
        cfg = cfg.updated(pipeline=dict(enabled=True,
                                        num_workers=args.num_workers))
    sess = Heta(cfg)

    g = sess.build_graph()
    learnable_rows = sum(g.num_nodes.values())
    print(f"graph: {g.total_nodes:,} nodes / {g.total_edges:,} edges, "
          f"{len(g.relations)} relations")
    print(f"learnable parameters: {learnable_rows * 64 / 1e6:.1f}M rows×64 "
          f"(+ Adam states ×2)\n")

    t0 = time.time()
    m = sess.run()
    dt = time.time() - t0
    sess.close_pipeline()
    losses = m["losses"]
    k = max(1, len(losses) // 10)
    print(f"\nloss: first-{k}-avg {np.mean(losses[:k]):.4f} -> "
          f"last-{k}-avg {np.mean(losses[-k:]):.4f}")
    print(f"total {dt/60:.1f} min, median step {m['step_time_s']*1e3:.0f} ms")
    if m["pipeline"]:
        print(f"pipeline: {m['sampler_workers']} workers, "
              f"{m['samples_per_s']:,.0f} samples/s, "
              f"overlap {m['overlap_fraction']:.2f}")
    print(f"cache hit rates: { {t: round(r, 2) for t, r in m['hit_rates'].items()} }")


if __name__ == "__main__":
    main()
